"""Paper Fig. 2 + Fig. 3 analogue: data-parallel scaling of SGD training.

The paper times ResNet-50 SGD on 1..8 GPUs of a DGX-1 under (a) fixed
global batch 64 and (b) batch scaled 64 x #GPUs.  Here the same experiment
runs a conv-net Synkhronos program on N in {1,2,4,8} forced host devices
(one subprocess per N so the device count can differ), measuring per-call
wall time of the synk function.  On this 1-core container the measured
numbers show *overhead* scaling, not compute scaling, so the harness also
reports the DERIVED v5e roofline speedup for the same program (compute
term scales 1/N; all-reduce term from the gradient bytes at ICI bw) —
that derived column is the Fig. 3 analogue.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER = r"""
import os, sys, time, json
n = int(sys.argv[1]); batch_mode = sys.argv[2]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
import numpy as np
import jax, jax.numpy as jnp
import repro.core as synk

synk.fork()
B = 64 if batch_mode == "fixed" else 64 * n
rng = np.random.default_rng(0)
X = rng.normal(size=(B, 3, 32, 32)).astype(np.float32)
Y = rng.integers(0, 10, size=(B,)).astype(np.int32)

def init():
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    return {
        "c1": jax.random.normal(ks[0], (16, 3, 3, 3)) * 0.1,
        "c2": jax.random.normal(ks[1], (32, 16, 3, 3)) * 0.1,
        "w": jax.random.normal(ks[2], (32 * 8 * 8, 10)) * 0.01,
    }

def model(p, x):
    x = jax.lax.conv_general_dilated(x, p["c1"], (1, 1), "SAME")
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    x = jax.lax.conv_general_dilated(x, p["c2"], (1, 1), "SAME")
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    x = x.reshape(x.shape[0], -1)
    return x @ p["w"]

def grad_fn(x, y, p):
    def loss(p):
        logits = model(p, x)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return jax.grad(loss)(p)

params = init()
f = synk.function(grad_fn, [synk.Scatter(), synk.Scatter(), synk.Broadcast()],
                  synk.Reduce("mean"))
g = f(X, Y, params)                       # compile + warm
jax.block_until_ready(jax.tree.leaves(g)[0])
t0 = time.perf_counter(); iters = 10
for _ in range(iters):
    g = f(X, Y, params)
jax.block_until_ready(jax.tree.leaves(g)[0])
dt = (time.perf_counter() - t0) / iters
n_params = sum(x.size for x in jax.tree.leaves(params))
print(json.dumps({"n": n, "mode": batch_mode, "sec_per_call": dt,
                  "batch": B, "n_params": int(n_params)}))
"""


def run(n: int, mode: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", _WORKER, str(n), mode],
                       capture_output=True, text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def derived_speedup(n: int, mode: str, n_params: int) -> float:
    """v5e roofline model in the paper's regime (ResNet-50, batch 64:
    ~1.6e12 fwd+bwd FLOPs, 25.6M params): compute term scales with
    devices; ring all-reduce of the flat fp32 gradient at ICI bw; fixed
    per-call host overhead ~50us.  Mirrors paper Fig. 2/3 on v5e."""
    flops_1gpu = 3 * 8.2e9 * 64   # ResNet-50: 2x fwd flops x batch, fwd+bwd
    resnet_params = 25.6e6
    peak, ici = 197e12, 50e9
    overlap = 0.9                 # grad all-reduce overlaps bwd compute
    batch_scale = 1.0 if mode == "fixed" else n
    t_comp = flops_1gpu * batch_scale / n / peak
    t_coll = 0.0 if n == 1 else \
        (1 - overlap) * 2 * 4 * resnet_params * (n - 1) / n / ici
    t_host = 50e-6
    t1 = flops_1gpu / peak + t_host
    return t1 * batch_scale / (t_comp + t_coll + t_host)


def main(emit) -> None:
    base = {}
    for mode in ("fixed", "scaled"):
        for n in (1, 2, 4, 8):
            r = run(n, mode)
            if n == 1:
                base[mode] = r["sec_per_call"]
            measured = base[mode] * (r["batch"] / 64) / r["sec_per_call"]
            der = derived_speedup(n, mode, r["n_params"])
            emit(f"fig23/{mode}/gpus={n}", r["sec_per_call"] * 1e6,
                 f"speedup_measured={measured:.2f}x;speedup_derived_v5e={der:.2f}x")


if __name__ == "__main__":
    main(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
