"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU — timing
is indicative only; the derived column reports the v5e roofline time for
the same workload, which is what the kernel targets)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

PEAK, HBM = 197e12, 819e9


def _timeit(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main(emit) -> None:
    rng = np.random.default_rng(0)

    # flash attention: B1 S2048 H8 D128
    from repro.kernels import flash_attention
    B, S, H, Hk, D = 1, 2048, 8, 2, 128
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hk, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hk, D)).astype(np.float32))
    dt = _timeit(lambda *a: flash_attention(*a, causal=True), q, k, v)
    flops = 2 * 2 * B * H * S * S * D / 2      # causal halves
    emit("kernel/flash_attention/2k", dt * 1e6,
         f"v5e_roofline_us={flops / PEAK * 1e6:.1f}")

    from repro.kernels import rmsnorm_op
    x = jnp.asarray(rng.normal(size=(8192, 1024)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    dt = _timeit(rmsnorm_op, x, g)
    bytes_ = 2 * x.size * 4
    emit("kernel/rmsnorm/8192x1024", dt * 1e6,
         f"v5e_roofline_us={bytes_ / HBM * 1e6:.1f}")

    from repro.kernels import ssd_op
    Bs, Hs, T, P, G, N = 1, 4, 1024, 64, 1, 64
    xs = jnp.asarray(rng.normal(size=(Bs, Hs, T, P)).astype(np.float32))
    dts = jnp.asarray(rng.uniform(0.01, 0.1, size=(Bs, Hs, T)).astype(np.float32))
    A = jnp.asarray(-np.ones(Hs, np.float32))
    Bm = jnp.asarray(rng.normal(size=(Bs, G, T, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(Bs, G, T, N)).astype(np.float32))
    dt = _timeit(lambda *a: ssd_op(*a, chunk=128), xs, dts, A, Bm, Cm)
    chunk = 128
    flops = Bs * Hs * (T / chunk) * (2 * chunk * chunk * N + 2 * chunk * chunk * P
                                     + 4 * chunk * N * P)
    emit("kernel/ssd/1k", dt * 1e6, f"v5e_roofline_us={flops / PEAK * 1e6:.2f}")

    from repro.kernels import flat_adam_op
    n = 1 << 20
    p = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    gr = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    m = jnp.zeros(n, jnp.float32)
    vv = jnp.zeros(n, jnp.float32)
    step = jnp.array([1], jnp.int32)
    dt = _timeit(lambda *a: flat_adam_op(*a, lr=1e-3), p, gr, m, vv, step)
    bytes_ = 7 * n * 4
    emit("kernel/flat_adam/1M", dt * 1e6,
         f"v5e_roofline_us={bytes_ / HBM * 1e6:.1f}")


if __name__ == "__main__":
    main(lambda n, us, x: print(f"{n},{us:.1f},{x}"))
