"""Overlap + dispatch benchmark: bucketed flat-gradient engine vs the
monolithic flat all-reduce, and SynkFunction dispatch overhead cold vs
cached.  Emits machine-readable JSON so the perf trajectory is tracked
PR-over-PR.

Runs on a forced 8-device host mesh (the env var must be set before jax
initializes, so run this module as a script — ``benchmarks/run.py`` spawns
it as a subprocess).

    python benchmarks/overlap_bench.py --smoke --json BENCH_overlap.json

JSON schema (all times are medians over --iters):
    meta:       devices / backend / jax version / config / smoke flag
    step_ms:    per-train-step wall time for each engine configuration
                (monolithic flat, bucketed flat, zero flat, legacy gspmd)
                + the bucket counts that produced them
    dispatch:   SynkFunction overhead — cold_ms (build+compile+run),
                cached_us (steady-state per call), presharded_us (per call
                when device_put is skippable), and the function's counters
"""
from __future__ import annotations

import os

# append (not setdefault): a pre-existing XLA_FLAGS (e.g. --xla_dump_to)
# must not suppress the forced host device count
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import argparse     # noqa: E402
import json         # noqa: E402
import statistics   # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _median_ms(fn, iters: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(ts)


# ---------------------------------------------------------------------------
# Train-step: monolithic vs bucketed vs zero vs legacy
# ---------------------------------------------------------------------------


def bench_step(smoke: bool, iters: int) -> dict:
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import _mk
    from repro.models.common import ShardRules
    from repro.optim import OptConfig
    from repro.train.loop import init_sharded
    from repro.train.step import TrainSettings, jit_train_step

    cfg = get_smoke_config("smollm-360m")
    B, S = (16, 8) if smoke else (64, 32)
    mesh = _mk((jax.device_count(), 1), ("data", "model"))
    shape = ShapeConfig("bench", "train", S, B)   # (seq_len, global_batch)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, size=(B, S + 1)).astype(np.int32)

    # bucket_mb chosen so "bucketed" yields several buckets on the smoke
    # model while "monolithic" is guaranteed one bucket
    variants = {
        "monolithic_flat": (TrainSettings(faithful=True),
                            OptConfig(kind="adam", lr=1e-3, bucket_mb=1 << 12)),
        "bucketed_flat": (TrainSettings(faithful=True),
                          OptConfig(kind="adam", lr=1e-3, bucket_mb=0.05)),
        "zero_flat": (TrainSettings(flat_engine="zero"),
                      OptConfig(kind="adam", lr=1e-3, bucket_mb=0.05)),
        "legacy_gspmd": (TrainSettings(faithful=True, flat_engine="off"),
                         OptConfig(kind="adam", lr=1e-3)),
    }
    out: dict = {"global_batch": B, "seq_len": S, "config": "smollm-360m/smoke"}
    for name, (settings, opt) in variants.items():
        rules = ShardRules.for_mesh(mesh, faithful=settings.faithful)
        stepf, _, in_sh = jit_train_step(
            cfg, mesh, rules, opt, shape, settings, donate=False)
        params, opt_state = init_sharded(cfg, mesh, rules, opt, 0, settings)
        batch = {"tokens": jax.device_put(tokens, in_sh[2]["tokens"])}
        state = {"p": params, "o": opt_state}

        def one_step():
            state["p"], state["o"], m = stepf(state["p"], state["o"], batch)
            jax.block_until_ready(m["loss"])

        t0 = time.perf_counter()
        one_step()  # includes compile
        compile_ms = (time.perf_counter() - t0) * 1e3
        out[name] = {
            "step_ms": _median_ms(one_step, iters),
            "first_call_ms": compile_ms,
            "engine": stepf._flat_engine,
            "num_buckets": (stepf._flat_buckets.num_buckets
                            if stepf._flat_buckets else None),
        }
    return out


# ---------------------------------------------------------------------------
# Dispatch: SynkFunction per-call overhead, cold vs cached
# ---------------------------------------------------------------------------


def bench_dispatch(smoke: bool, iters: int) -> dict:
    import repro.core as synk

    ctx = synk.fork()
    n = ctx.n_data
    rows = 8 * n if smoke else 128 * n
    rng = np.random.default_rng(1)
    x = rng.normal(size=(rows, 64)).astype(np.float32)
    w = rng.normal(size=(64,)).astype(np.float32)

    f = synk.function(lambda x, w: jnp.mean(x @ w),
                      [synk.Scatter(), synk.Broadcast()], synk.Reduce("mean"))

    t0 = time.perf_counter()
    jax.block_until_ready(f(x, w))          # build + AOT compile + run
    cold_ms = (time.perf_counter() - t0) * 1e3

    k = max(iters * 10, 50)

    def cached():
        jax.block_until_ready(f(x, w))

    cached_ms = _median_ms(cached, k)

    xs = jax.device_put(x, ctx.sharding(ctx.data_spec(None)))
    ws = jax.device_put(w, ctx.sharding(jax.sharding.PartitionSpec()))

    def presharded():
        jax.block_until_ready(f(xs, ws))

    presharded_ms = _median_ms(presharded, k)

    return {
        "cold_ms": cold_ms,
        "cached_us": cached_ms * 1e3,
        "presharded_us": presharded_ms * 1e3,
        "rows": rows,
        "stats": dict(f.stats),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / few iters (CI mode)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--json", default=None, help="also write JSON to this path")
    args = ap.parse_args(argv)
    iters = args.iters or (3 if args.smoke else 10)

    report = {
        "meta": {
            "bench": "overlap",
            "devices": jax.device_count(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "smoke": bool(args.smoke),
            "iters": iters,
        },
        "step_ms": bench_step(args.smoke, iters),
        "dispatch": bench_dispatch(args.smoke, iters),
    }
    text = json.dumps(report, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(text + "\n")
    return report


if __name__ == "__main__":
    main()
