"""Roofline report: reads the dry-run artifacts (launch/dryrun.py output)
and emits the three-term table per (arch x shape x mesh) cell."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load_cells(pattern: str = "*.json"):
    cells = []
    for f in sorted(glob.glob(os.path.join(ART, pattern))):
        r = json.load(open(f))
        if r.get("skipped") or "error" in r:
            continue
        cells.append(r)
    return cells


def advice(r) -> str:
    """One sentence: what would move the dominant term down (per spec)."""
    t = r["terms"]
    dom = t["dominant"]
    kind = r.get("kind", "")
    if dom == "compute_s":
        if t.get("useful_flops_ratio", 1) < 0.8:
            return ("cut recompute/redundant FLOPs: lighter remat policy or "
                    "causal block-skipping (kernels/flash_attention)")
        return "already compute-bound near useful FLOPs: scale chips or batch"
    if dom == "memory_s":
        if kind == "decode":
            return ("decode is cache-bandwidth bound: quantize KV (bf16->int8) "
                    "or batch more sequences per step")
        if t.get("useful_flops_ratio", 1) < 0.2:
            return ("eliminate redundant per-axis compute (pure-DP rules for "
                    "chip-sized models) before touching kernels")
        return ("fuse elementwise chains into the Pallas kernels "
                "(flash_attention/rmsnorm keep interiors in VMEM) and drop "
                "fp32 intermediates to bf16")
    return ("reduce collective wire: fewer microbatch slices (weight "
            "re-gathers scale with num_slices), bf16 params/grads on TPU, "
            "and overlap via latency-hiding scheduler")


def main(emit) -> None:
    cells = load_cells()
    if not cells:
        emit("roofline/no_artifacts", 0.0, "run launch/dryrun.py first")
        return
    for r in cells:
        t = r["terms"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("faithful"):
            name += "/faithful"
        if r.get("variants"):
            name += "/" + "-".join(r["variants"])
        bound_us = t["bound_s"] * 1e6
        emit(
            name, bound_us,
            f"dom={t['dominant'].replace('_s','')};"
            f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
            f"collective_s={t['collective_s']:.4f};"
            f"useful_ratio={t['useful_flops_ratio']:.3f};"
            f"roofline_frac={t['roofline_fraction']:.4f};"
            f"next={advice(r)}",
        )


if __name__ == "__main__":
    main(lambda n, us, x: print(f"{n},{us:.1f},{x}"))
