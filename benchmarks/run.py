"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig23_scaling    — paper Fig. 2/3 (DP speedup, fixed + scaled batch)
  table1_profile   — paper Table 1 (loop decomposition w/ blocking)
  roofline_report  — §Roofline terms per dry-run cell (this repo's tables)
  kernel_bench     — Pallas kernel micro-benchmarks
  overlap          — bucketed flat-gradient engine + dispatch overhead
                     (subprocess on a forced 8-device host mesh; also
                     writes BENCH_overlap.json to the repo root)
  serve            — continuous-batching serve engine vs the static-batch
                     loop on a Poisson arrival trace (subprocess, 8-device
                     host mesh; writes BENCH_serve.json to the repo root)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def run_overlap(emit, smoke: bool = True,
                out_json: str | None = None) -> bool:
    """Run overlap_bench in a subprocess (it needs XLA_FLAGS set before jax
    initializes) and surface headline numbers as CSV rows."""
    out_json = out_json or os.path.join(REPO, "BENCH_overlap.json")
    cmd = [sys.executable, os.path.join(REPO, "benchmarks", "overlap_bench.py"),
           "--json", out_json]
    if smoke:
        cmd.append("--smoke")
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=1800)
    if r.returncode != 0:
        print(r.stdout[-2000:], file=sys.stderr)
        print(r.stderr[-2000:], file=sys.stderr)
        return False
    with open(out_json) as fh:
        rep = json.load(fh)
    for name in ("monolithic_flat", "bucketed_flat", "zero_flat", "legacy_gspmd"):
        row = rep["step_ms"].get(name)
        if row:
            emit(f"overlap/{name}", row["step_ms"] * 1e3,
                 f"buckets={row['num_buckets']}")
    d = rep["dispatch"]
    emit("overlap/dispatch_cold", d["cold_ms"] * 1e3, "build+compile")
    emit("overlap/dispatch_cached", d["cached_us"], "steady-state")
    emit("overlap/dispatch_presharded", d["presharded_us"], "device_put skipped")
    return True


def run_serve(emit, smoke: bool = True, out_json: str | None = None) -> bool:
    """Run serve_bench in a subprocess (XLA_FLAGS before jax init) and
    surface the headline rows as CSV."""
    out_json = out_json or os.path.join(REPO, "BENCH_serve.json")
    cmd = [sys.executable, os.path.join(REPO, "benchmarks", "serve_bench.py"),
           "--json", out_json]
    if smoke:
        cmd.append("--smoke")
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=1800)
    if r.returncode != 0:
        print(r.stdout[-2000:], file=sys.stderr)
        print(r.stderr[-2000:], file=sys.stderr)
        return False
    with open(out_json) as fh:
        rep = json.load(fh)
    for name, row in rep["modes"].items():
        emit(f"serve/{name}", 1e6 / row["tokens_per_s"],
             f"p99={row['p99_ms_per_token']:.0f}ms/tok")
    h = rep["headline"]
    emit("serve/speedup_vs_static", h["speedup_vs_static"] * 100,
         "continuous/static tokens-per-s x100")
    emit("serve/kv_reserved_ratio_paged",
         h["kv_reserved_ratio_paged_vs_slotted"] * 100,
         "paged/slotted KV reservation x100")
    # full acceptance: >= 2x tokens/s at equal-or-better p99 per-token
    # latency, zero executable builds after warmup on every engine mode,
    # paged greedy parity, and a real paged reservation saving
    ok = (h["speedup_vs_static"] >= 2.0
          and h["p99_ratio_vs_static"] <= 1.0
          and h["steady_builds_delta"] == 0
          and h["paged_steady_builds_delta"] == 0
          and h["paged_greedy_parity"]
          and h["kv_reserved_ratio_paged_vs_slotted"] < 1.0)
    if not ok:
        print(f"serve bench FAILED acceptance: {h}", file=sys.stderr)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig23,table1,roofline,kernels,overlap,serve")
    ap.add_argument("--full-overlap", action="store_true",
                    help="overlap bench at full (non-smoke) sizes")
    args = ap.parse_args()
    want = set(
        (args.only or "fig23,table1,roofline,kernels,overlap,serve").split(","))

    print("name,us_per_call,derived")
    ok = True
    if "overlap" in want:
        try:
            ok = run_overlap(emit, smoke=not args.full_overlap) and ok
        except Exception:
            ok = False
            traceback.print_exc()
    if "serve" in want:
        try:
            ok = run_serve(emit, smoke=not args.full_overlap) and ok
        except Exception:
            ok = False
            traceback.print_exc()
    if "roofline" in want:
        from benchmarks import roofline_report
        roofline_report.main(emit)
    if "kernels" in want:
        from benchmarks import kernel_bench
        kernel_bench.main(emit)
    if "table1" in want:
        from benchmarks import table1_profile
        try:
            table1_profile.main(emit)
        except Exception:
            ok = False
            traceback.print_exc()
    if "fig23" in want:
        from benchmarks import fig23_scaling
        try:
            fig23_scaling.main(emit)
        except Exception:
            ok = False
            traceback.print_exc()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
