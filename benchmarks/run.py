"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig23_scaling    — paper Fig. 2/3 (DP speedup, fixed + scaled batch)
  table1_profile   — paper Table 1 (loop decomposition w/ blocking)
  roofline_report  — §Roofline terms per dry-run cell (this repo's tables)
  kernel_bench     — Pallas kernel micro-benchmarks
"""
from __future__ import annotations

import argparse
import sys
import traceback


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig23,table1,roofline,kernels")
    args = ap.parse_args()
    want = set((args.only or "fig23,table1,roofline,kernels").split(","))

    print("name,us_per_call,derived")
    ok = True
    if "roofline" in want:
        from benchmarks import roofline_report
        roofline_report.main(emit)
    if "kernels" in want:
        from benchmarks import kernel_bench
        kernel_bench.main(emit)
    if "table1" in want:
        from benchmarks import table1_profile
        try:
            table1_profile.main(emit)
        except Exception:
            ok = False
            traceback.print_exc()
    if "fig23" in want:
        from benchmarks import fig23_scaling
        try:
            fig23_scaling.main(emit)
        except Exception:
            ok = False
            traceback.print_exc()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
