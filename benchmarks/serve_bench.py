"""Serve benchmark: continuous batching vs the static-batch loop, fused
vs host sampling, on a Poisson arrival trace.  Writes BENCH_serve.json.

Runs on a forced 8-device host mesh (env var must be set before jax
initializes, so run as a script — ``benchmarks/run.py`` spawns it).

    python benchmarks/serve_bench.py --smoke --json BENCH_serve.json

Workload: requests with heterogeneous prompt lengths and a heavy-tailed
token-budget distribution (most requests short, every 8th long) arriving
on a Poisson clock fast enough to keep the system load-saturated.  This is
the regime continuous batching targets: a static batch runs every lane to
the batch's *max* budget (dead slots decode padding) and a whole batch
head-of-line-blocks behind its straggler, while the slotted engine admits
from the queue the step a lane frees.

Modes:
    static_batch      legacy loop: batches of ``max_slots`` in arrival
                      order, prefill+decode executables built ONCE and
                      reused (a *stronger* baseline than ``generate()``,
                      which re-traces every call), host-side sampling.
    continuous_fused  the serve engine: slotted cache, fused sampling,
                      AOT-cached dispatch.  The headline.
    continuous_host   engine with ``fused_sampling=False``: full logits
                      round-trip + host sampling per step (ablates the
                      fused sampler).
    continuous_paged  paged (block-table) KV layout with the pool sized
                      to HALF the slotted worst case — ``kv_reserved_
                      bytes`` drops accordingly while greedy tokens stay
                      identical (asserted into ``headline.paged_greedy_
                      parity``; ci.sh gates on it).
    continuous_paged_chunked
                      paged + chunked prefill: prompts admitted in fixed
                      chunks interleaved with decode steps.
    continuous_paged_shared
                      paged engine on the SHARED-PREFIX trace (every
                      prompt opens with the same 48-token system prompt)
                      with prefix caching OFF — the comparator for the
                      prefix mode's prefill-token savings.
    continuous_paged_prefix
                      same shared-prefix trace with the refcounted prefix
                      cache ON: admissions match the published block
                      chains and prefill only their unique suffix.
                      ``timed.prefix_hit_rate`` and the prefill-token
                      ratio vs continuous_paged_shared are the headline
                      (ci.sh gates hit rate > 0 and ratio < 0.6).
    continuous_paged_preempt
                      paged engine with ``admission="preempt"`` and the
                      pool squeezed to ~3/8 of worst case: lanes admit on
                      immediate need and decode growth evicts the lowest-
                      priority lane back to the queue (exact greedy
                      parity still required — ``headline.preempt_greedy_
                      parity``).
    continuous_tiered the preempting pool with the host RAM tier ON:
                      every preemption DMAs the victim's KV blocks to
                      pinned host buffers and re-admission restores them
                      O(bytes copied) — ``restores > 0`` with zero
                      ``replayed_tokens`` and zero re-prefill, tokens
                      bitwise the roomy-pool paged drive (ci.sh gates
                      ``tiered_o_copy_resume``, parity, builds-flat).
    continuous_recurrent
                      the SAME engine serving the ``ssm`` family (xLSTM
                      smoke config): lanes are per-lane recurrent state
                      with no seq axis — admission snapshots the state at
                      the prompt end, eviction zeroes the lane.  Greedy
                      parity vs solo ``generate_static`` and a preempt-
                      and-requeue resume parity are asserted into
                      ``headline.recurrent_greedy_parity`` /
                      ``recurrent_preempt_parity`` (ci.sh gates both).
                      f32 compute so the engine-vs-static comparison is
                      exact.
    continuous_hybrid the engine serving zamba2 (``hybrid``): each lane
                      composes a slotted KV segment (shared attention
                      block) with recurrent mamba leaves — one cache
                      dict, same admission/eviction flow
                      (``headline.hybrid_greedy_parity``).
    continuous_router the multi-replica front-end: a ``Router`` over 3
                      slotted replicas (one weight copy, one shared
                      AotCache), all requests submitted up front, one
                      replica KILLED at a fixed tick mid-drive and a
                      second drained + reinstated.  Every request must
                      finish ``ok`` on a survivor with greedy tokens
                      bitwise the fault-free single-engine drive
                      (``failover_parity``), zero requests lost, and no
                      steady-state builds (ci.sh gates all four plus
                      failovers > 0).
    continuous_chaos  the paged engine under a seeded ``FaultPlan``
                      (injected non-finite logits, failed allocs, prefill
                      and sched-push faults) with a generous retry budget:
                      every request must still terminate ``ok`` with
                      greedy tokens identical to the fault-free drive of
                      the same trace, while dispatching purely from the
                      prebuilt cache.  Reports ``recovery_overhead`` (wall
                      vs the fault-free drive) — the cost of quarantine +
                      preempt-and-replay recovery (ci.sh gates faults
                      fired > 0, parity, and builds-flat).
    continuous_spec   speculative decoding: the slotted engine with a
                      draft model (same architecture, params mixed toward
                      a fresh init) proposing ``spec_k`` tokens per lane
                      per round, the target verifying all of them in ONE
                      fused dispatch.  Greedy tokens must be bitwise the
                      sequential engine's on the same trace (the accept
                      rule only ever commits the target's own argmax);
                      the headline is ``tokens_per_decode_dispatch`` —
                      committed tokens per lane-round, exactly 1.0 for
                      the sequential engine, > 1.0 when speculation pays
                      (ci.sh gates parity, acceptance > 0, rejections
                      > 0, tpd > 1.0, builds-flat).
    continuous_traced the tracing-overhead harness: a submit-all drain
                      drive untraced (best of 2) vs with the FULL observer
                      armed (span tracer + flight-recorder sink).  Tokens
                      must stay bitwise identical and ``traced_overhead_
                      ratio`` (decode steps/s, traced / untraced) must
                      stay >= 0.95 (ci.sh-gated); the span timeline lands
                      as BENCH_serve_trace.json (Chrome/Perfetto) +
                      .jsonl next to the report.

Every continuous mode reports ``kv_reserved_bytes`` (cache HBM actually
allocated) and ``kv_peak_used_bytes`` (high-water mark of positions/blocks
holding live KV) — the reserved-vs-used gap is the over-allocation the
paged layout removes.

Each engine mode prebuilds its executables (``engine.prebuild()``) and
then runs the trace twice: a warmup pass (arrivals collapsed to t=0),
then the timed pass.  ``steady_builds_delta`` must be 0 for EVERY mode —
the AOT dispatch cache may not miss in steady state (scripts/ci.sh fails
otherwise).  Prefix hits and preemptions make the executable schedule
timing-dependent, which is exactly why prebuild (not the warmup trace) is
what guarantees coverage.  ``timed`` holds the timed-pass-only counter
deltas (prefill tokens, prefix hits, preemptions, COW copies).

Metrics per mode: useful tokens/s (every request's budgeted tokens /
wall), and p50/p99 per-token latency ((last-token-time - arrival) /
tokens, over requests).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


@dataclasses.dataclass
class _Req:
    rid: int
    arrival: float          # seconds from trace start
    prompt: np.ndarray
    budget: int             # tokens to generate


def make_trace(n_requests: int, vocab: int, *, seed: int = 0,
               rate: float = 60.0, long_every: int = 8,
               long_budget: int = 64) -> list[_Req]:
    """Poisson arrivals; short budgets with a deterministic heavy tail
    (every ``long_every``-th request wants ``long_budget`` tokens)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(4, 25))
        budget = long_budget if i % long_every == long_every - 1 \
            else int(rng.integers(2, 6))
        out.append(_Req(i, t, rng.integers(0, vocab, plen).astype(np.int32), budget))
    return out


def make_shared_trace(n_requests: int, vocab: int, *, seed: int = 1,
                      rate: float = 60.0, prefix_len: int = 48,
                      long_every: int = 4, long_budget: int = 16) -> list[_Req]:
    """The prefix-cache workload: every prompt opens with the SAME
    ``prefix_len``-token system prompt followed by a short unique tail —
    the chat-serving shape where prefix caching pays (near-zero-cost
    system prompts)."""
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, vocab, prefix_len).astype(np.int32)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        tail = rng.integers(0, vocab, int(rng.integers(4, 17))).astype(np.int32)
        budget = long_budget if i % long_every == long_every - 1 \
            else int(rng.integers(2, 6))
        out.append(_Req(i, t, np.concatenate([sysp, tail]), budget))
    return out


def _percentiles(lat_ms: list[float]) -> dict:
    a = np.asarray(lat_ms)
    return {"p50_ms_per_token": float(np.percentile(a, 50)),
            "p99_ms_per_token": float(np.percentile(a, 99))}


def _summary(wall: float, tokens: int, lat_ms: list[float], **extra) -> dict:
    return {"tokens_per_s": tokens / wall, "useful_tokens": tokens,
            "wall_s": wall, **_percentiles(lat_ms), **extra}


# ---------------------------------------------------------------------------
# Static-batch baseline
# ---------------------------------------------------------------------------


def run_static(cfg, mesh, rules, params, trace: list[_Req], *,
               batch: int, temperature: float = 0.0) -> dict:
    """Fixed batches in arrival order; every lane decodes to the batch-max
    budget; host sampling.  Executables are built once and reused (already
    generous to the baseline — ``generate()`` re-traces per call)."""
    from repro.configs.base import ShapeConfig
    from repro.serve.step import jit_decode_step, jit_prefill

    s_pad = max(r.prompt.size for r in trace)
    max_new = max(r.budget for r in trace)
    max_len = s_pad + max_new
    prefill_fn, _ = jit_prefill(
        cfg, mesh, rules, ShapeConfig("bench", "prefill", s_pad, batch),
        max_len=max_len)
    decode_fn, _ = jit_decode_step(
        cfg, mesh, rules, ShapeConfig("bench", "decode", max_len, batch),
        donate=True)

    def one_batch(group: list[_Req], budget: int):
        """Returns per-step wall times of each produced token row."""
        prompts = np.zeros((batch, s_pad), np.int32)
        for j, r in enumerate(group):
            prompts[j, : r.prompt.size] = r.prompt
        cache, logits = prefill_fn(params, jnp.asarray(prompts), None)
        times = []
        for t in range(budget):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # host round-trip
            np.asarray(tok)
            times.append(time.perf_counter())
            logits, cache = decode_fn(params, cache, tok, jnp.int32(s_pad + t))
        return times

    # warmup: compile both executables
    one_batch(trace[:batch], 1)

    lat_ms, tokens = [], 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), batch):
        group = trace[i : i + batch]
        # head-of-line: the batch launches once its last member has arrived
        wait = t0 + group[-1].arrival - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        budget = max(r.budget for r in group)
        times = one_batch(group, budget)
        for r in group:
            done = times[r.budget - 1]
            lat_ms.append((done - (t0 + r.arrival)) / r.budget * 1e3)
            tokens += r.budget
    wall = time.perf_counter() - t0
    return _summary(wall, tokens, lat_ms, batches=len(range(0, len(trace), batch)),
                    steps=sum(max(r.budget for r in trace[i:i + batch])
                              for i in range(0, len(trace), batch)))


# ---------------------------------------------------------------------------
# Continuous engine
# ---------------------------------------------------------------------------


_TIMED_KEYS = ("prefill_tokens", "prefix_hit_tokens", "prefix_lookup_tokens",
               "preemptions", "cow_copies")


def run_continuous(cfg, mesh, rules, params, trace: list[_Req], *,
                   max_slots: int, max_len: int, fused: bool,
                   temperature: float = 0.0, kv_layout: str = "slotted",
                   page_size: int = 16, num_blocks: int | None = None,
                   prefill_chunk: int = 0, prefix_cache: bool = False,
                   admission: str = "deficit", aot=None) -> dict:
    from repro.serve import EngineConfig, ServeEngine

    engine = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=max_slots, max_len=max_len,
                     fused_sampling=fused, kv_layout=kv_layout,
                     page_size=page_size, num_blocks=num_blocks,
                     prefill_chunk=prefill_chunk, prefix_cache=prefix_cache,
                     admission=admission),
        aot=aot,
    )
    # compile everything up front: prefix hits and preemption resumes make
    # the executable schedule timing-dependent, so a warmup *trace* can't
    # guarantee coverage — prebuild makes builds-flat an invariant
    engine.prebuild()

    def play(timed: bool):
        i = 0
        t0 = time.perf_counter()
        while i < len(trace) or engine.has_work():
            now = time.perf_counter() - t0
            while i < len(trace) and (not timed or trace[i].arrival <= now):
                r = trace[i]
                engine.submit(r.prompt, max_new_tokens=r.budget,
                              temperature=temperature, rid=r.rid + (0 if timed else 10**6))
                i += 1
            if not engine.step() and timed and i < len(trace):
                time.sleep(max(0.0, t0 + trace[i].arrival - time.perf_counter()))
        return t0, time.perf_counter() - t0

    play(timed=False)                       # warmup (also warms the prefix cache)
    builds_warm = engine.stats["builds"]
    warm_counters = {k: engine.counters[k] for k in _TIMED_KEYS}
    t0, wall = play(timed=True)
    builds_delta = engine.stats["builds"] - builds_warm
    timed = {k: engine.counters[k] - warm_counters[k] for k in _TIMED_KEYS}
    timed["prefix_hit_rate"] = (
        timed["prefix_hit_tokens"] / timed["prefix_lookup_tokens"]
        if timed["prefix_lookup_tokens"] else 0.0)

    lat_ms, tokens = [], 0
    for r in trace:
        c = engine.completions[r.rid]
        lat_ms.append((c.token_times[-1] - (t0 + r.arrival)) / len(c.tokens) * 1e3)
        tokens += len(c.tokens)
    return _summary(wall, tokens, lat_ms, steady_builds_delta=builds_delta,
                    kv_reserved_bytes=engine.kv_reserved_bytes,
                    kv_peak_used_bytes=engine.stats["kv_peak_used_bytes"],
                    timed=timed, stats=engine.stats,
                    metrics=engine.obs.metrics.snapshot())


def run_chaos(cfg, mesh, rules, params, trace: list[_Req], *,
              max_slots: int, max_len: int, page_size: int,
              num_blocks: int, aot=None) -> dict:
    """Fault-injected drive of the paged engine vs the identical fault-
    free drive: all requests must recover to ``ok`` with bitwise greedy
    tokens (quarantine + preempt-and-replay), and the recovery overhead
    is the walls' ratio.  ``max_retries`` is generous so injected faults
    exhaust the budget only with astronomically bad luck."""
    from repro.serve import EngineConfig, FaultPlan, ServeEngine

    ec = EngineConfig(max_slots=max_slots, max_len=max_len,
                      kv_layout="paged", page_size=page_size,
                      num_blocks=num_blocks, max_retries=8)

    def drive(faults):
        eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot,
                          faults=faults)
        eng.prebuild()
        b0 = eng.stats["builds"]
        rids = [eng.submit(r.prompt, max_new_tokens=r.budget)
                for r in trace]
        t0 = time.perf_counter()
        eng.drain()
        return eng, rids, time.perf_counter() - t0, \
            eng.stats["builds"] - b0

    clean_eng, rids, clean_wall, _ = drive(None)
    plan = FaultPlan(0, {"decode_logits": 0.02, "prefill": 0.05,
                         "alloc": 0.02, "sched_push": 0.05})
    eng, rids2, wall, builds_delta = drive(plan)

    want = [list(clean_eng.completions[r].tokens) for r in rids]
    got = [list(eng.completions[r].tokens) for r in rids2]
    statuses = [eng.completions[r].status for r in rids2]
    tokens = sum(len(t) for t in got)
    return {
        "tokens_per_s": tokens / wall, "useful_tokens": tokens,
        "wall_s": wall, "clean_wall_s": clean_wall,
        "recovery_overhead": wall / clean_wall,
        "faults_fired": plan.total_fired,
        "fault_sites": plan.stats(),
        "faults_detected": eng.counters["faults_detected"],
        "retries": eng.counters["retries"],
        "preemptions": eng.counters["preemptions"],
        "all_ok": all(s == "ok" for s in statuses),
        "token_parity": got == want,
        "steady_builds_delta": builds_delta,
        "metrics": eng.obs.metrics.snapshot(),
    }


def run_tiered(cfg, mesh, rules, params, trace: list[_Req], *,
               max_slots: int, max_len: int, page_size: int,
               num_blocks: int, preempt_blocks: int, aot=None) -> dict:
    """The host-tier drive: paged engine with ``admission="preempt"`` on
    the same squeezed pool as ``continuous_paged_preempt``, plus a host
    RAM tier — every preemption DMAs the victim's KV blocks to host
    buffers and re-admission restores them O(bytes copied) instead of
    replaying the stream.

    The O(copy) claim is asserted structurally, not by timing: with the
    tier on, preemptions must be > 0 (the pool forces them) while
    ``replayed_tokens`` stays 0 (no restored lane ever re-decoded a
    recorded token) and ``prefill_tokens`` equals the trace's prompt
    tokens exactly (no re-prefill on resume).  Tokens must remain
    bitwise the roomy-pool paged drive's — spill/restore is invisible in
    the output."""
    from repro.serve import EngineConfig, ServeEngine

    def drive(ec):
        eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
        eng.prebuild()
        b0 = eng.stats["builds"]
        rids = [eng.submit(r.prompt, max_new_tokens=r.budget)
                for r in trace]
        t0 = time.perf_counter()
        eng.drain()
        return (eng, [list(eng.completions[r].tokens) for r in rids],
                time.perf_counter() - t0, eng.stats["builds"] - b0)

    # parity target: the roomy half pool never preempts, so its streams
    # are the uninterrupted reference (dispatches purely from cache)
    _, want, _, _ = drive(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks))
    eng, got, wall, builds_delta = drive(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=preempt_blocks,
        admission="preempt", host_tier=True))

    c = eng.counters
    prompt_tokens = sum(int(r.prompt.size) for r in trace)
    tokens = sum(len(t) for t in got)
    return {
        "tokens_per_s": tokens / wall, "useful_tokens": tokens,
        "wall_s": wall,
        "token_parity": got == want,
        "all_ok": all(eng.completions[r].status == "ok"
                      for r in eng.completions),
        "preemptions": c["preemptions"],
        "spills": c["spills"], "restores": c["restores"],
        "spill_drops": c["spill_drops"],
        "spilled_bytes": c["spilled_bytes"],
        "restored_bytes": c["restored_bytes"],
        "replayed_tokens": c["replayed_tokens"],
        "prefill_tokens": c["prefill_tokens"],
        "prompt_tokens": prompt_tokens,
        # every resume was a copy: no replay decode steps, no re-prefill
        "o_copy_resume": bool(
            c["restores"] > 0 and c["replayed_tokens"] == 0
            and c["prefill_tokens"] == prompt_tokens),
        "steady_builds_delta": builds_delta,
        "host_tier": eng.stats["host_tier"],
        "kv_reserved_bytes": eng.kv_reserved_bytes,
        "kv_peak_used_bytes": eng.stats["kv_peak_used_bytes"],
        "metrics": eng.obs.metrics.snapshot(),
    }


def run_router(cfg, mesh, rules, params, trace: list[_Req], *,
               replicas: int, max_slots: int, max_len: int,
               kill_tick: int = 2, drain_tick: int = 5,
               reinstate_tick: int = 8, aot=None) -> dict:
    """Router fleet chaos drive: ``replicas`` slotted engines behind the
    front-end, one killed deterministically at ``kill_tick`` (its
    in-flight requests rebuild from the router's stream mirrors — the
    engine is never touched again), another drained at ``drain_tick``
    and reinstated at ``reinstate_tick``.  Failover parity means every
    recovered stream is bitwise the fault-free single-engine drive."""
    from repro.serve import EngineConfig, Router, RouterConfig, ServeEngine

    ec = EngineConfig(max_slots=max_slots, max_len=max_len)

    ref = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
    rids = [ref.submit(r.prompt, max_new_tokens=r.budget, rid=r.rid)
            for r in trace]
    ref.drain()
    want = [list(ref.completions[r].tokens) for r in rids]

    router = Router(
        cfg, mesh, rules, params, ec,
        RouterConfig(replicas=replicas, shed_queue_depth=len(trace) + 1),
        aot=aot)
    router.prebuild()
    b0 = router.stats["builds"]
    for r in trace:
        router.submit(r.prompt, max_new_tokens=r.budget, rid=r.rid)
    migrated = 0
    t0 = time.perf_counter()
    guard = 0
    while router.has_work():
        router.step()
        router.check_invariants()
        if router.tick == kill_tick:
            router.kill(replicas - 1)
        if router.tick == drain_tick and \
                router.replicas[0].state == "live" and \
                sum(h.state == "live" for h in router.replicas) >= 2:
            migrated = router.drain(0)
        if router.tick == reinstate_tick and \
                router.replicas[0].state == "drained":
            router.reinstate(0)
        guard += 1
        assert guard < 100_000, "router drive failed to drain"
    wall = time.perf_counter() - t0

    got = [list(router.completions[r.rid].tokens) for r in trace]
    statuses = [router.completions[r.rid].status for r in trace]
    tokens = sum(len(t) for t in got)
    c = router.counters
    return {
        "tokens_per_s": tokens / wall, "useful_tokens": tokens,
        "wall_s": wall, "replicas": replicas,
        "requests_lost": c["submitted"] - len(router.completions),
        "all_ok": all(s == "ok" for s in statuses),
        "failover_parity": got == want,
        "failovers": c["failovers"],
        "migrated": migrated,
        "replicas_dead": c["replicas_dead"],
        "cache_routed": c["cache_routed"],
        "steady_builds_delta": router.stats["builds"] - b0,
        "metrics": router.obs.metrics.snapshot(),
    }


def run_traced(cfg, mesh, rules, params, trace: list[_Req], *,
               max_slots: int, max_len: int, aot=None,
               trace_json: str | None = None,
               trace_jsonl: str | None = None) -> dict:
    """Tracing-overhead harness: the same submit-all drain drive on the
    slotted engine, untraced (best of 2 fresh drives) vs with the FULL
    observer armed (tracer + flight-recorder sink).  Greedy tokens must
    be bitwise identical, decode-step counts equal, builds flat, the
    event stream must validate (spans balanced, every request's timeline
    terminal-complete), and the decode steps/s ratio is the headline —
    ci.sh gates it >= 0.95 (tracing must stay a host-side ring append,
    never a sync)."""
    from repro.obs import Observer, to_chrome_trace, to_jsonl, validate
    from repro.serve import EngineConfig, ServeEngine

    ec = EngineConfig(max_slots=max_slots, max_len=max_len)

    def drive(obs):
        eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot, obs=obs)
        eng.prebuild()
        b0 = eng.stats["builds"]
        rids = [eng.submit(r.prompt, max_new_tokens=r.budget)
                for r in trace]
        t0 = time.perf_counter()
        eng.drain()
        wall = time.perf_counter() - t0
        toks = [list(eng.completions[r].tokens) for r in rids]
        return (eng, toks, wall, eng.stats["builds"] - b0,
                eng.counters["decode_steps"])

    # untraced baseline: best of 2 fresh drives (first absorbs allocator
    # and page-cache noise; the drive itself is deterministic)
    base_walls, base_builds = [], 0
    for _ in range(2):
        _, base_toks, w, bd, base_steps = drive(None)
        base_walls.append(w)
        base_builds = max(base_builds, bd)
    obs = Observer.full(name="engine")
    eng, toks, wall, builds_delta, steps = drive(obs)
    info = validate(obs.tracer.events)
    if trace_json:
        to_chrome_trace(obs.tracer.events, trace_json)
    if trace_jsonl:
        to_jsonl(obs.tracer.events, trace_jsonl)

    tokens = sum(len(t) for t in toks)
    base_wall = min(base_walls)
    return {
        "tokens_per_s": tokens / wall, "useful_tokens": tokens,
        "wall_s": wall, "untraced_wall_s": base_wall,
        "decode_steps": int(steps),
        "decode_steps_match": int(steps) == int(base_steps),
        # equal step counts, so steps/s ratio reduces to the wall ratio
        "traced_overhead_ratio": base_wall / wall,
        "token_parity": toks == base_toks,
        "trace_events": info["events"], "trace_spans": info["spans"],
        "trace_requests": info["requests"],
        "steady_builds_delta": max(base_builds, builds_delta),
        "metrics": eng.obs.metrics.snapshot(),
    }


def run_spec(cfg, mesh, rules, params, draft_params, trace: list[_Req], *,
             max_slots: int, max_len: int, spec_k: int, aot=None) -> dict:
    """Speculative-decoding drive vs the identical sequential engine on
    the same submit-all trace.  Parity is structural (greedy verify
    commits only the target's own argmax, so drafts gate chain LENGTH,
    never token identity) and asserted bitwise here; the speedup claim
    is ``tokens_per_decode_dispatch`` = committed tokens / lane-rounds —
    the sequential engine is exactly 1.0 per lane-round, so anything
    above 1.0 means each verify dispatch amortizes over >1 committed
    token."""
    from repro.serve import EngineConfig, ServeEngine

    def drive(ec, dp):
        eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot,
                          draft_params=dp)
        eng.prebuild()
        b0 = eng.stats["builds"]
        rids = [eng.submit(r.prompt, max_new_tokens=r.budget)
                for r in trace]
        t0 = time.perf_counter()
        eng.drain()
        return (eng, [list(eng.completions[r].tokens) for r in rids],
                time.perf_counter() - t0, eng.stats["builds"] - b0)

    base = EngineConfig(max_slots=max_slots, max_len=max_len)
    _, want, seq_wall, _ = drive(base, None)
    eng, got, wall, builds_delta = drive(
        dataclasses.replace(base, spec_draft=cfg, spec_k=spec_k),
        draft_params)

    c = eng.counters
    st = eng.stats
    tokens = sum(len(t) for t in got)
    return {
        "tokens_per_s": tokens / wall, "useful_tokens": tokens,
        "wall_s": wall, "sequential_wall_s": seq_wall,
        "spec_k": spec_k,
        "token_parity": got == want,
        "spec_rounds": c["spec_rounds"],
        "spec_drafted": c["spec_drafted"],
        "spec_accepted": c["spec_accepted"],
        "spec_rejected": c["spec_rejected"],
        "spec_committed": c["spec_committed"],
        "acceptance_rate": st["spec_acceptance_rate"],
        "tokens_per_decode_dispatch": st["tokens_per_decode_dispatch"],
        "steady_builds_delta": builds_delta,
        "metrics": eng.obs.metrics.snapshot(),
    }


def check_recurrent_parity(cfg, trace: list[_Req], *, max_slots: int,
                           max_len: int, preempt_tick: int = 3) -> dict:
    """Greedy parity of the recurrent/hybrid slot engine vs the legacy
    ``generate_static`` loop (each request solo), staggered through fewer
    lanes than requests — plus a preempt-and-requeue drive whose resumed
    streams must still match.  Runs on a single-device mesh (the tested
    exact-parity configuration; the throughput modes use the full local
    mesh)."""
    from repro.launch.mesh import single_device_mesh
    from repro.models import registry
    from repro.models.common import ShardRules
    from repro.serve import EngineConfig, ServeConfig, ServeEngine, \
        generate_static

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    reqs = trace[: 2 * max_slots + 1]           # lanes get reused
    solo = [
        list(generate_static(cfg, mesh, rules, params, r.prompt[None],
                             serve=ServeConfig(max_new_tokens=r.budget))[0])
        for r in reqs
    ]

    def drive(preempts: bool):
        eng = ServeEngine(cfg, mesh, rules, params,
                          EngineConfig(max_slots=max_slots, max_len=max_len))
        rids = [eng.submit(r.prompt, max_new_tokens=r.budget) for r in reqs]
        steps = 0
        # a bounded preemption schedule (not periodic: a replay that spans
        # the period would requeue forever and never make progress)
        schedule = {preempt_tick, 3 * preempt_tick + 2} if preempts else set()
        while eng.has_work():
            eng.step()
            steps += 1
            assert steps < 5000, "parity drive failed to drain"
            if steps in schedule:
                victim = next((i for i, s in enumerate(eng.slots)
                               if s is not None), None)
                if victim is not None:
                    eng.preempt(victim)
        return [list(eng.completions[r].tokens) for r in rids], eng

    plain, _ = drive(preempts=False)
    resumed, peng = drive(preempts=True)
    want = [[int(t) for t in row] for row in solo]
    return {
        "greedy_parity": plain == want,
        "preempt_parity": resumed == want,
        "parity_check_preemptions": peng.counters["preemptions"],
        "replayed_tokens": peng.counters["replayed_tokens"],
    }


def check_paged_parity(cfg, mesh, rules, params, trace: list[_Req], *,
                       max_slots: int, max_len: int, page_size: int,
                       num_blocks: int, preempt_blocks: int,
                       prefill_chunk: int, aot=None) -> dict:
    """Greedy token-for-token parity of every paged engine mode — whole-
    bucket, chunked, prefix-cached, and preempting (squeezed pool) —
    against the slotted engine on a staggered submit-all trace.  Sharing
    the bench modes' AotCache means this compiles nothing new."""
    from repro.serve import EngineConfig, ServeEngine

    reqs = trace[: 2 * max_slots + 1]          # lanes get reused
    prompts = [r.prompt for r in reqs]
    budgets = [r.budget for r in reqs]

    def tokens(ec):
        eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
        rids = [eng.submit(p, max_new_tokens=b)
                for p, b in zip(prompts, budgets)]
        eng.drain()
        return [list(eng.completions[r].tokens) for r in rids], eng

    want, _ = tokens(EngineConfig(max_slots=max_slots, max_len=max_len))
    paged, _ = tokens(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks))
    chunked, _ = tokens(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks,
        prefill_chunk=prefill_chunk))
    prefix, _ = tokens(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks, prefix_cache=True))
    preempt, peng = tokens(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=preempt_blocks,
        admission="preempt"))
    return {
        "paged_greedy_parity": paged == want and chunked == want,
        "prefix_greedy_parity": prefix == want,
        "preempt_greedy_parity": preempt == want,
        "parity_check_preemptions": peng.counters["preemptions"],
    }


# ---------------------------------------------------------------------------


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI sizes")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.launch.mesh import local_mesh
    from repro.models import registry
    from repro.models.common import ShardRules

    # smoke model with the REAL vocab: serving moves (slots, V) logits per
    # step, so a toy vocab would hide exactly the cost the fused sampler
    # removes (the host logits round-trip)
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), vocab=49_152)
    mesh = local_mesh()
    rules = ShardRules.for_mesh(mesh)
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))

    n_requests = args.requests or (24 if args.smoke else 64)
    max_slots, long_budget = 8, 64
    trace = make_trace(n_requests, cfg.vocab, long_budget=long_budget)
    shared_trace = make_shared_trace(n_requests, cfg.vocab)
    page_size = 16
    max_len = max(max(r.prompt.size + r.budget for r in trace),
                  max(r.prompt.size + r.budget for r in shared_trace))
    max_len = -(-max_len // page_size) * page_size     # paged wants a multiple
    # paged pool: HALF the slotted worst-case reservation — the layout's
    # point is that the mixed-length trace never needs the worst case —
    # rounded up to the device count (the engine shards the block dim)
    worst_blocks = max_slots * (max_len // page_size)
    ndev = jax.device_count()
    num_blocks = -(-(worst_blocks // 2 + 1) // ndev) * ndev
    # preempting pool: squeezed to just above the largest single request's
    # worst case (the admission floor), so concurrent lanes constantly
    # overcommit it — admission stops gating on worst-case commitments and
    # decode growth preempts instead of waiting
    max_wc = max(-(-(r.prompt.size + r.budget - 1) // page_size)
                 for r in trace)
    preempt_blocks = -(-(max_wc + 2) // ndev) * ndev
    prefill_chunk = 2 * page_size

    report = {
        "meta": {
            "bench": "serve",
            "devices": jax.device_count(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "smoke": bool(args.smoke),
            "config": cfg.name,
            "trace": {
                "n_requests": n_requests, "max_slots": max_slots,
                "max_len": max_len, "long_budget": long_budget,
                "useful_tokens": sum(r.budget for r in trace),
                "page_size": page_size, "num_blocks": num_blocks,
                "preempt_blocks": preempt_blocks,
                "prefill_chunk": prefill_chunk,
                "shared_prefix_len": 48,
            },
        },
        "modes": {},
    }
    # one AotCache across every engine: each mode compiles only its own
    # executables (keys carry layout/fused/chunk), and the parity check at
    # the end dispatches entirely from cache
    from repro.core.aot import AotCache
    aot = AotCache("serve-bench")
    report["modes"]["static_batch"] = run_static(
        cfg, mesh, rules, params, trace, batch=max_slots)
    report["modes"]["continuous_fused"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, aot=aot)
    report["modes"]["continuous_host"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=False, aot=aot)
    report["modes"]["continuous_paged"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks, aot=aot)
    report["modes"]["continuous_paged_chunked"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks,
        prefill_chunk=prefill_chunk, aot=aot)
    # the shared-prefix pair: identical trace, prefix cache off vs on —
    # the prefill-token delta is the work the cache removes
    report["modes"]["continuous_paged_shared"] = run_continuous(
        cfg, mesh, rules, params, shared_trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks, aot=aot)
    report["modes"]["continuous_paged_prefix"] = run_continuous(
        cfg, mesh, rules, params, shared_trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks, prefix_cache=True,
        aot=aot)
    report["modes"]["continuous_paged_preempt"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=preempt_blocks,
        admission="preempt", aot=aot)
    report["modes"]["continuous_tiered"] = run_tiered(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, page_size=page_size, num_blocks=num_blocks,
        preempt_blocks=preempt_blocks, aot=aot)
    report["modes"]["continuous_chaos"] = run_chaos(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, page_size=page_size, num_blocks=num_blocks,
        aot=aot)
    report["modes"]["continuous_router"] = run_router(
        cfg, mesh, rules, params, trace, replicas=3, max_slots=max_slots,
        max_len=max_len, aot=aot)
    # tracing overhead + trace artifacts next to the report json
    trace_json = trace_jsonl = None
    if args.json:
        base = args.json[:-5] if args.json.endswith(".json") else args.json
        trace_json, trace_jsonl = base + "_trace.json", base + "_trace.jsonl"
    report["modes"]["continuous_traced"] = run_traced(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, aot=aot, trace_json=trace_json,
        trace_jsonl=trace_jsonl)
    # speculative decoding: draft = same arch mixed 10% toward a fresh
    # init — close enough to accept routinely, far enough to reject
    # routinely, so both the commit and rollback paths are timed
    draft_params = jax.tree.map(
        lambda a, b: 0.9 * a + 0.1 * b, params,
        registry.get_module(cfg).init(cfg, jax.random.PRNGKey(1)))
    report["modes"]["continuous_spec"] = run_spec(
        cfg, mesh, rules, params, draft_params, trace,
        max_slots=max_slots, max_len=max_len, spec_k=3, aot=aot)

    # --- recurrent state kinds: the SAME engine over ssm + hybrid ------
    # f32 compute so the engine-vs-generate_static parity checks are
    # exact; the smoke vocabs stay native (these modes measure the family
    # axis + dispatch flatness, not sampler-fetch bandwidth)
    rec_parity = {}
    for mode_name, arch in (("continuous_recurrent", "xlstm-1.3b"),
                            ("continuous_hybrid", "zamba2-1.2b")):
        fcfg = dataclasses.replace(
            get_smoke_config(arch), compute_dtype="float32")
        fparams = registry.get_module(fcfg).init(fcfg, jax.random.PRNGKey(0))
        ftrace = make_trace(max(n_requests // 2, 8), fcfg.vocab,
                            long_budget=32)
        fmax_len = max(r.prompt.size + r.budget for r in ftrace) + 8
        faot = AotCache(mode_name)
        report["modes"][mode_name] = run_continuous(
            fcfg, mesh, ShardRules.for_mesh(mesh), fparams, ftrace,
            max_slots=max_slots, max_len=fmax_len, fused=True, aot=faot)
        rec_parity[mode_name] = check_recurrent_parity(
            fcfg, ftrace, max_slots=max(max_slots // 4, 2),
            max_len=fmax_len)

    st, cf = report["modes"]["static_batch"], report["modes"]["continuous_fused"]
    pg = report["modes"]["continuous_paged"]
    px = report["modes"]["continuous_paged_prefix"]
    shared = report["modes"]["continuous_paged_shared"]
    parity = check_paged_parity(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, page_size=page_size, num_blocks=num_blocks,
        preempt_blocks=preempt_blocks, prefill_chunk=prefill_chunk, aot=aot)
    report["headline"] = {
        "speedup_vs_static": cf["tokens_per_s"] / st["tokens_per_s"],
        "p99_ratio_vs_static": cf["p99_ms_per_token"] / st["p99_ms_per_token"],
        "fused_speedup_vs_host": (
            cf["tokens_per_s"]
            / report["modes"]["continuous_host"]["tokens_per_s"]),
        "steady_builds_delta": cf["steady_builds_delta"],
        "paged_steady_builds_delta": max(
            pg["steady_builds_delta"],
            report["modes"]["continuous_paged_chunked"]["steady_builds_delta"]),
        # ALL engine modes must dispatch purely from cache after warmup
        "all_steady_builds_delta": max(
            row["steady_builds_delta"]
            for name, row in report["modes"].items()
            if name != "static_batch"),
        "kv_reserved_ratio_paged_vs_slotted": (
            pg["kv_reserved_bytes"] / cf["kv_reserved_bytes"]),
        # prefix caching: timed-pass hit rate and the fraction of prefill
        # tokens still computed vs the no-cache engine on the same trace
        "prefix_cache_hit_rate": px["timed"]["prefix_hit_rate"],
        "prefix_prefill_token_ratio": (
            px["timed"]["prefill_tokens"]
            / max(shared["timed"]["prefill_tokens"], 1)),
        "preemptions_timed": (
            report["modes"]["continuous_paged_preempt"]["timed"]["preemptions"]),
        # host tier: every preemption resumed O(copy) — restores > 0 with
        # zero replayed decode steps and zero re-prefill — bitwise the
        # roomy-pool paged streams, dispatching purely from cache
        "tiered_token_parity": (
            report["modes"]["continuous_tiered"]["token_parity"]),
        "tiered_restores": report["modes"]["continuous_tiered"]["restores"],
        "tiered_replayed_tokens": (
            report["modes"]["continuous_tiered"]["replayed_tokens"]),
        "tiered_o_copy_resume": (
            report["modes"]["continuous_tiered"]["o_copy_resume"]),
        "tiered_steady_builds_delta": (
            report["modes"]["continuous_tiered"]["steady_builds_delta"]),
        # chaos: injected faults must all recover — same greedy tokens as
        # the fault-free drive, no retraces, bounded overhead
        "chaos_faults_fired": (
            report["modes"]["continuous_chaos"]["faults_fired"]),
        "chaos_all_ok": report["modes"]["continuous_chaos"]["all_ok"],
        "chaos_token_parity": (
            report["modes"]["continuous_chaos"]["token_parity"]),
        "chaos_recovery_overhead": (
            report["modes"]["continuous_chaos"]["recovery_overhead"]),
        "chaos_steady_builds_delta": (
            report["modes"]["continuous_chaos"]["steady_builds_delta"]),
        # router fleet: a replica crash mid-drive must be invisible in
        # the output — zero lost, all ok, bitwise the single-engine run
        "router_requests_lost": (
            report["modes"]["continuous_router"]["requests_lost"]),
        "router_all_ok": report["modes"]["continuous_router"]["all_ok"],
        "router_failover_parity": (
            report["modes"]["continuous_router"]["failover_parity"]),
        "router_failovers": (
            report["modes"]["continuous_router"]["failovers"]),
        "router_migrated": report["modes"]["continuous_router"]["migrated"],
        "router_steady_builds_delta": (
            report["modes"]["continuous_router"]["steady_builds_delta"]),
        # recurrent/hybrid: slot serving generalized beyond the lm
        # families — engine-vs-static greedy parity, preempt-resume
        # parity (ssm), and dispatch flatness across both new modes
        "recurrent_greedy_parity":
            rec_parity["continuous_recurrent"]["greedy_parity"],
        "recurrent_preempt_parity":
            rec_parity["continuous_recurrent"]["preempt_parity"],
        "recurrent_preemptions":
            rec_parity["continuous_recurrent"]["parity_check_preemptions"],
        "hybrid_greedy_parity":
            rec_parity["continuous_hybrid"]["greedy_parity"],
        "hybrid_preempt_parity":
            rec_parity["continuous_hybrid"]["preempt_parity"],
        "recurrent_steady_builds_delta": max(
            report["modes"]["continuous_recurrent"]["steady_builds_delta"],
            report["modes"]["continuous_hybrid"]["steady_builds_delta"]),
        # speculative decoding: bitwise greedy parity with the
        # sequential engine while each verify dispatch commits > 1
        # token per lane-round on average
        "spec_greedy_parity": (
            report["modes"]["continuous_spec"]["token_parity"]),
        "spec_acceptance_rate": (
            report["modes"]["continuous_spec"]["acceptance_rate"]),
        "spec_tokens_per_decode_dispatch": (
            report["modes"]["continuous_spec"]
            ["tokens_per_decode_dispatch"]),
        "spec_steady_builds_delta": (
            report["modes"]["continuous_spec"]["steady_builds_delta"]),
        # observability: a fully-armed observer (tracer + flight
        # recorder) must not perturb the engine — bitwise tokens, no new
        # builds, and >= 95% of the untraced decode rate (ci.sh-gated)
        "traced_overhead_ratio": (
            report["modes"]["continuous_traced"]["traced_overhead_ratio"]),
        "traced_token_parity": (
            report["modes"]["continuous_traced"]["token_parity"]),
        "traced_steady_builds_delta": (
            report["modes"]["continuous_traced"]["steady_builds_delta"]),
        **parity,
    }
    # compile-time profile: the slowest AOT builds across the shared cache
    report["meta"]["slowest_builds"] = aot.top_builds(5)
    report["meta"]["aot_build_s_total"] = round(aot.build_s_total, 3)
    text = json.dumps(report, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(text + "\n")
    return report


if __name__ == "__main__":
    main()
