"""Serve benchmark: continuous batching vs the static-batch loop, fused
vs host sampling, on a Poisson arrival trace.  Writes BENCH_serve.json.

Runs on a forced 8-device host mesh (env var must be set before jax
initializes, so run as a script — ``benchmarks/run.py`` spawns it).

    python benchmarks/serve_bench.py --smoke --json BENCH_serve.json

Workload: requests with heterogeneous prompt lengths and a heavy-tailed
token-budget distribution (most requests short, every 8th long) arriving
on a Poisson clock fast enough to keep the system load-saturated.  This is
the regime continuous batching targets: a static batch runs every lane to
the batch's *max* budget (dead slots decode padding) and a whole batch
head-of-line-blocks behind its straggler, while the slotted engine admits
from the queue the step a lane frees.

Modes:
    static_batch      legacy loop: batches of ``max_slots`` in arrival
                      order, prefill+decode executables built ONCE and
                      reused (a *stronger* baseline than ``generate()``,
                      which re-traces every call), host-side sampling.
    continuous_fused  the serve engine: slotted cache, fused sampling,
                      AOT-cached dispatch.  The headline.
    continuous_host   engine with ``fused_sampling=False``: full logits
                      round-trip + host sampling per step (ablates the
                      fused sampler).
    continuous_paged  paged (block-table) KV layout with the pool sized
                      to HALF the slotted worst case — ``kv_reserved_
                      bytes`` drops accordingly while greedy tokens stay
                      identical (asserted into ``headline.paged_greedy_
                      parity``; ci.sh gates on it).
    continuous_paged_chunked
                      paged + chunked prefill: prompts admitted in fixed
                      chunks interleaved with decode steps.

Every continuous mode reports ``kv_reserved_bytes`` (cache HBM actually
allocated) and ``kv_peak_used_bytes`` (high-water mark of positions/blocks
holding live KV) — the reserved-vs-used gap is the over-allocation the
paged layout removes.

Each engine mode runs the trace twice: a warmup pass (arrivals collapsed
to t=0) that compiles every executable the trace needs, then the timed
pass.  ``steady_builds_delta`` must be 0 — the AOT dispatch cache may not
miss in steady state (scripts/ci.sh fails otherwise).

Metrics per mode: useful tokens/s (every request's budgeted tokens /
wall), and p50/p99 per-token latency ((last-token-time - arrival) /
tokens, over requests).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


@dataclasses.dataclass
class _Req:
    rid: int
    arrival: float          # seconds from trace start
    prompt: np.ndarray
    budget: int             # tokens to generate


def make_trace(n_requests: int, vocab: int, *, seed: int = 0,
               rate: float = 60.0, long_every: int = 8,
               long_budget: int = 64) -> list[_Req]:
    """Poisson arrivals; short budgets with a deterministic heavy tail
    (every ``long_every``-th request wants ``long_budget`` tokens)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(4, 25))
        budget = long_budget if i % long_every == long_every - 1 \
            else int(rng.integers(2, 6))
        out.append(_Req(i, t, rng.integers(0, vocab, plen).astype(np.int32), budget))
    return out


def _percentiles(lat_ms: list[float]) -> dict:
    a = np.asarray(lat_ms)
    return {"p50_ms_per_token": float(np.percentile(a, 50)),
            "p99_ms_per_token": float(np.percentile(a, 99))}


def _summary(wall: float, tokens: int, lat_ms: list[float], **extra) -> dict:
    return {"tokens_per_s": tokens / wall, "useful_tokens": tokens,
            "wall_s": wall, **_percentiles(lat_ms), **extra}


# ---------------------------------------------------------------------------
# Static-batch baseline
# ---------------------------------------------------------------------------


def run_static(cfg, mesh, rules, params, trace: list[_Req], *,
               batch: int, temperature: float = 0.0) -> dict:
    """Fixed batches in arrival order; every lane decodes to the batch-max
    budget; host sampling.  Executables are built once and reused (already
    generous to the baseline — ``generate()`` re-traces per call)."""
    from repro.configs.base import ShapeConfig
    from repro.serve.step import jit_decode_step, jit_prefill

    s_pad = max(r.prompt.size for r in trace)
    max_new = max(r.budget for r in trace)
    max_len = s_pad + max_new
    prefill_fn, _ = jit_prefill(
        cfg, mesh, rules, ShapeConfig("bench", "prefill", s_pad, batch),
        max_len=max_len)
    decode_fn, _ = jit_decode_step(
        cfg, mesh, rules, ShapeConfig("bench", "decode", max_len, batch),
        donate=True)

    def one_batch(group: list[_Req], budget: int):
        """Returns per-step wall times of each produced token row."""
        prompts = np.zeros((batch, s_pad), np.int32)
        for j, r in enumerate(group):
            prompts[j, : r.prompt.size] = r.prompt
        cache, logits = prefill_fn(params, jnp.asarray(prompts), None)
        times = []
        for t in range(budget):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # host round-trip
            np.asarray(tok)
            times.append(time.perf_counter())
            logits, cache = decode_fn(params, cache, tok, jnp.int32(s_pad + t))
        return times

    # warmup: compile both executables
    one_batch(trace[:batch], 1)

    lat_ms, tokens = [], 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), batch):
        group = trace[i : i + batch]
        # head-of-line: the batch launches once its last member has arrived
        wait = t0 + group[-1].arrival - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        budget = max(r.budget for r in group)
        times = one_batch(group, budget)
        for r in group:
            done = times[r.budget - 1]
            lat_ms.append((done - (t0 + r.arrival)) / r.budget * 1e3)
            tokens += r.budget
    wall = time.perf_counter() - t0
    return _summary(wall, tokens, lat_ms, batches=len(range(0, len(trace), batch)),
                    steps=sum(max(r.budget for r in trace[i:i + batch])
                              for i in range(0, len(trace), batch)))


# ---------------------------------------------------------------------------
# Continuous engine
# ---------------------------------------------------------------------------


def run_continuous(cfg, mesh, rules, params, trace: list[_Req], *,
                   max_slots: int, max_len: int, fused: bool,
                   temperature: float = 0.0, kv_layout: str = "slotted",
                   page_size: int = 16, num_blocks: int | None = None,
                   prefill_chunk: int = 0, aot=None) -> dict:
    from repro.serve import EngineConfig, ServeEngine

    engine = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=max_slots, max_len=max_len,
                     fused_sampling=fused, kv_layout=kv_layout,
                     page_size=page_size, num_blocks=num_blocks,
                     prefill_chunk=prefill_chunk),
        aot=aot,
    )

    def play(timed: bool):
        i = 0
        t0 = time.perf_counter()
        while i < len(trace) or engine.has_work():
            now = time.perf_counter() - t0
            while i < len(trace) and (not timed or trace[i].arrival <= now):
                r = trace[i]
                engine.submit(r.prompt, max_new_tokens=r.budget,
                              temperature=temperature, rid=r.rid + (0 if timed else 10**6))
                i += 1
            if not engine.step() and timed and i < len(trace):
                time.sleep(max(0.0, t0 + trace[i].arrival - time.perf_counter()))
        return t0, time.perf_counter() - t0

    play(timed=False)                       # warmup: compiles every bucket
    builds_warm = engine.stats["builds"]
    t0, wall = play(timed=True)
    builds_delta = engine.stats["builds"] - builds_warm

    lat_ms, tokens = [], 0
    for r in trace:
        c = engine.completions[r.rid]
        lat_ms.append((c.token_times[-1] - (t0 + r.arrival)) / len(c.tokens) * 1e3)
        tokens += len(c.tokens)
    return _summary(wall, tokens, lat_ms, steady_builds_delta=builds_delta,
                    kv_reserved_bytes=engine.kv_reserved_bytes,
                    kv_peak_used_bytes=engine.stats["kv_peak_used_bytes"],
                    stats=engine.stats)


def check_paged_parity(cfg, mesh, rules, params, trace: list[_Req], *,
                       max_slots: int, max_len: int, page_size: int,
                       num_blocks: int, prefill_chunk: int,
                       aot=None) -> bool:
    """Greedy token-for-token parity of the paged engine (both prefill
    modes) against the slotted engine on a staggered submit-all trace.
    Sharing the bench modes' AotCache means this compiles nothing new."""
    from repro.serve import EngineConfig, ServeEngine

    reqs = trace[: 2 * max_slots + 1]          # lanes get reused
    prompts = [r.prompt for r in reqs]
    budgets = [r.budget for r in reqs]

    def tokens(ec):
        eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
        rids = [eng.submit(p, max_new_tokens=b)
                for p, b in zip(prompts, budgets)]
        eng.drain()
        return [list(eng.completions[r].tokens) for r in rids]

    want = tokens(EngineConfig(max_slots=max_slots, max_len=max_len))
    paged = tokens(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks))
    chunked = tokens(EngineConfig(
        max_slots=max_slots, max_len=max_len, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks,
        prefill_chunk=prefill_chunk))
    return paged == want and chunked == want


# ---------------------------------------------------------------------------


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI sizes")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.launch.mesh import local_mesh
    from repro.models import registry
    from repro.models.common import ShardRules

    # smoke model with the REAL vocab: serving moves (slots, V) logits per
    # step, so a toy vocab would hide exactly the cost the fused sampler
    # removes (the host logits round-trip)
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), vocab=49_152)
    mesh = local_mesh()
    rules = ShardRules.for_mesh(mesh)
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))

    n_requests = args.requests or (24 if args.smoke else 64)
    max_slots, long_budget = 8, 64
    trace = make_trace(n_requests, cfg.vocab, long_budget=long_budget)
    page_size = 16
    max_len = max(r.prompt.size for r in trace) + long_budget
    max_len = -(-max_len // page_size) * page_size     # paged wants a multiple
    # paged pool: HALF the slotted worst-case reservation — the layout's
    # point is that the mixed-length trace never needs the worst case —
    # rounded up to the device count (the engine shards the block dim)
    worst_blocks = max_slots * (max_len // page_size)
    ndev = jax.device_count()
    num_blocks = -(-(worst_blocks // 2 + 1) // ndev) * ndev
    prefill_chunk = 2 * page_size

    report = {
        "meta": {
            "bench": "serve",
            "devices": jax.device_count(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "smoke": bool(args.smoke),
            "config": cfg.name,
            "trace": {
                "n_requests": n_requests, "max_slots": max_slots,
                "max_len": max_len, "long_budget": long_budget,
                "useful_tokens": sum(r.budget for r in trace),
                "page_size": page_size, "num_blocks": num_blocks,
                "prefill_chunk": prefill_chunk,
            },
        },
        "modes": {},
    }
    # one AotCache across every engine: each mode compiles only its own
    # executables (keys carry layout/fused/chunk), and the parity check at
    # the end dispatches entirely from cache
    from repro.core.aot import AotCache
    aot = AotCache("serve-bench")
    report["modes"]["static_batch"] = run_static(
        cfg, mesh, rules, params, trace, batch=max_slots)
    report["modes"]["continuous_fused"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, aot=aot)
    report["modes"]["continuous_host"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=False, aot=aot)
    report["modes"]["continuous_paged"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks, aot=aot)
    report["modes"]["continuous_paged_chunked"] = run_continuous(
        cfg, mesh, rules, params, trace, max_slots=max_slots,
        max_len=max_len, fused=True, kv_layout="paged",
        page_size=page_size, num_blocks=num_blocks,
        prefill_chunk=prefill_chunk, aot=aot)

    st, cf = report["modes"]["static_batch"], report["modes"]["continuous_fused"]
    pg = report["modes"]["continuous_paged"]
    report["headline"] = {
        "speedup_vs_static": cf["tokens_per_s"] / st["tokens_per_s"],
        "p99_ratio_vs_static": cf["p99_ms_per_token"] / st["p99_ms_per_token"],
        "fused_speedup_vs_host": (
            cf["tokens_per_s"]
            / report["modes"]["continuous_host"]["tokens_per_s"]),
        "steady_builds_delta": cf["steady_builds_delta"],
        "paged_steady_builds_delta": max(
            pg["steady_builds_delta"],
            report["modes"]["continuous_paged_chunked"]["steady_builds_delta"]),
        "kv_reserved_ratio_paged_vs_slotted": (
            pg["kv_reserved_bytes"] / cf["kv_reserved_bytes"]),
        "paged_greedy_parity": check_paged_parity(
            cfg, mesh, rules, params, trace, max_slots=max_slots,
            max_len=max_len, page_size=page_size, num_blocks=num_blocks,
            prefill_chunk=prefill_chunk, aot=aot),
    }
    text = json.dumps(report, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(text + "\n")
    return report


if __name__ == "__main__":
    main()
