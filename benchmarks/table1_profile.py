"""Paper Table 1 analogue: decomposed profile of the training loop.

The paper profiles (with CUDA launch blocking): Total / Theano Function /
Shuffle / Straggler / All-Reduce.  The analogue decomposes a Synkhronos
training iteration on 8 forced host devices into: total, function
(compute), shuffle (input indexing), and gradient all-reduce — each timed
with blocking, mirroring the table rows.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
import repro.core as synk

synk.fork()
rng = np.random.default_rng(0)
N, D, B = 4096, 256, 512
X = synk.data(rng.normal(size=(N, D)).astype(np.float32))
Y = synk.data(rng.normal(size=(N,)).astype(np.float32))
w = rng.normal(size=(D,)).astype(np.float32) * 0.1

def grad_fn(x, y, w):
    return jax.grad(lambda w: jnp.mean((x @ w - y) ** 2))(w)

f = synk.function(grad_fn, [synk.Scatter(), synk.Scatter(), synk.Broadcast()],
                  synk.Reduce(None))          # keep per-worker grads
params = synk.distribute({"w": w})

def bench(fn, iters=20):
    fn(); fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0] if out is not None else ())
    return (time.perf_counter() - t0) / iters

idx = rng.permutation(N)[:B]
t_shuffle = bench(lambda: (np.random.default_rng(1).permutation(N)[:B], None)[1] or X.excerpt(idx))
t_fn = bench(lambda: f(X, Y, w, batch=idx))
t_ar = bench(lambda: synk.all_reduce(params, "avg").tree)
t_total = bench(lambda: synk.all_reduce(
    synk.LocalValues({"g": f(X, Y, w, batch=idx)[0]}), "avg").tree)
print(json.dumps({"total": t_total, "function": t_fn, "shuffle": t_shuffle,
                  "all_reduce": t_ar}))
"""


def main(emit) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", _WORKER],
                       capture_output=True, text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    d = json.loads(r.stdout.strip().splitlines()[-1])
    total = d["total"]
    for row in ("total", "function", "shuffle", "all_reduce"):
        emit(f"table1/{row}", d[row] * 1e6,
             f"fraction_of_total={d[row] / total:.3f}")


if __name__ == "__main__":
    main(lambda n, us, x: print(f"{n},{us:.1f},{x}"))
