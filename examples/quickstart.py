"""Quickstart: the Synkhronos-JAX core API in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
(set XLA_FLAGS=--xla_force_host_platform_device_count=8 to see real
data parallelism on CPU)
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as synk

# 1. fork(): build the device mesh (paper: one process per GPU)
ctx = synk.fork()
print(f"workers: {ctx.n_data}")

# 2. write a SERIAL function — no device code, no collectives
def loss_and_grad(x, y, w):
    def loss(w):
        return jnp.mean((x @ w - y) ** 2)
    return jax.value_and_grad(loss)(w)

# 3. synk.function: scatter inputs, run everywhere, reduce outputs
f = synk.function(
    loss_and_grad,
    inputs=[synk.Scatter(), synk.Scatter(), synk.Broadcast()],
    outputs=synk.Reduce("mean"),
)

rng = np.random.default_rng(0)
X = rng.normal(size=(512, 32)).astype(np.float32)
true_w = rng.normal(size=(32,)).astype(np.float32)
Y = (X @ true_w).astype(np.float32)

# 4. synk.data: host staging buffers (paper's OS shared memory, §4.1)
dX, dY = synk.data(X), synk.data(Y)

w = np.zeros(32, np.float32)
for step in range(60):
    idx = rng.permutation(len(dX))[:128]         # §5.2 input indexing
    loss, grad = f(dX, dY, w, batch=idx)
    # §5.1 input slicing (grad accumulation) works the same way:
    #   loss, grad = f(dX, dY, w, batch=idx, num_slices=4)
    w = w - 0.1 * np.asarray(grad)
    if step % 20 == 0:
        print(f"step {step:3d}  loss {float(loss):.5f}")

print(f"final loss {float(loss):.6f} (should approach 0)")
assert float(loss) < 1e-3
print("OK")
