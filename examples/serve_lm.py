"""Batched serving example: prefill a batch of prompts and decode with the
sequence-sharded KV cache path (the same decode_step the dry-run lowers).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b
(uses the reduced smoke config on CPU; greedy decoding is deterministic).

Slot serving is state-kind generic — recurrent families route through
the same engine:

    PYTHONPATH=src python examples/serve_lm.py --arch xlstm-1.3b
    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b

``--kv-layout paged`` serves the same batch through the block-table KV
cache (optionally with ``--prefill-chunk N`` chunked admission) and
prints the reserved-vs-used KV bytes next to the tokens — greedy output
is identical to the slotted default.  Paged is KV-only: recurrent state
has no seq axis to page.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.mesh import single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.serve import EngineConfig, ServeConfig, ServeEngine, generate


def run_paged(cfg, mesh, rules, params, prompts, args):
    max_len = args.prompt_len + args.new_tokens
    max_len = -(-max_len // args.page_size) * args.page_size
    engine = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(
            max_slots=args.batch, max_len=max_len, kv_layout="paged",
            page_size=args.page_size, prefill_chunk=args.prefill_chunk,
            prefix_cache=args.prefix_cache, admission=args.admission,
        ),
    )
    out = engine.run(list(prompts), max_new_tokens=args.new_tokens,
                     temperature=args.temperature)
    s = engine.stats
    print(f"kv[paged]: {s['kv_peak_used_bytes']} bytes peak used / "
          f"{s['kv_reserved_bytes']} reserved  "
          f"(chunks={s['prefill_chunks']}, builds={s['builds']})")
    if args.prefix_cache:
        print(f"prefix cache: {s['prefix_hit_tokens']}/"
              f"{s['prefix_lookup_tokens']} prompt tokens served from cache "
              f"({s['cow_copies']} COW copies)")
    if args.admission == "preempt":
        print(f"preemptions: {s['preemptions']} (resumed {s['resumed']})")
    return np.stack(out, axis=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-layout", choices=("slotted", "paged"),
                    default="slotted")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: chunked prefill (paged layout only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted shared-prefix block reuse (paged only)")
    ap.add_argument("--admission", choices=("deficit", "preempt"),
                    default="deficit",
                    help="paged admission policy (preempt: evict-and-requeue)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    mod = registry.get_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "vlm":
        extra = rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        extra = rng.normal(size=(args.batch, cfg.enc_seq,
                                 cfg.d_model)).astype(np.float32)

    if args.kv_layout == "paged":
        if extra is not None or not registry.supports_paged_serving(cfg):
            raise SystemExit(
                "paged serving covers the lm KV families only (recurrent "
                "state has no seq axis to page)")
        out = run_paged(cfg, mesh, rules, params, prompts, args)
    else:
        out = generate(cfg, mesh, rules, params, prompts, extra,
                       ServeConfig(max_new_tokens=args.new_tokens,
                                   temperature=args.temperature))
    print(f"arch={cfg.name}  batch={args.batch}  new_tokens={args.new_tokens}  "
          f"kv_layout={args.kv_layout}")
    for i, row in enumerate(out):
        print(f"  seq{i}: {row.tolist()}")
    # determinism check for greedy decoding
    if args.temperature == 0.0:
        out2 = generate(cfg, mesh, rules, params, prompts, extra,
                        ServeConfig(max_new_tokens=args.new_tokens))
        assert np.array_equal(out, out2), \
            "greedy decode must be deterministic (and layout-independent)"
        print("deterministic: OK")


if __name__ == "__main__":
    main()
