"""Batched serving example: prefill a batch of prompts and decode with the
sequence-sharded KV cache path (the same decode_step the dry-run lowers).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b
(uses the reduced smoke config on CPU; greedy decoding is deterministic).
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.mesh import single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.serve import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    mod = registry.get_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "vlm":
        extra = rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        extra = rng.normal(size=(args.batch, cfg.enc_seq,
                                 cfg.d_model)).astype(np.float32)

    out = generate(cfg, mesh, rules, params, prompts, extra,
                   ServeConfig(max_new_tokens=args.new_tokens,
                               temperature=args.temperature))
    print(f"arch={cfg.name}  batch={args.batch}  new_tokens={args.new_tokens}")
    for i, row in enumerate(out):
        print(f"  seq{i}: {row.tolist()}")
    # determinism check for greedy decoding
    if args.temperature == 0.0:
        out2 = generate(cfg, mesh, rules, params, prompts, extra,
                        ServeConfig(max_new_tokens=args.new_tokens))
        assert np.array_equal(out, out2), "greedy decode must be deterministic"
        print("deterministic: OK")


if __name__ == "__main__":
    main()
