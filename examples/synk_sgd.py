"""Paper Appendix A, line for line: the Synkhronos multi-GPU SGD program.

Left (paper, Theano):                    Here (JAX):
    import synkhronos as synk                import repro.core as synk
    synk.fork()                              synk.fork()
    build_cnn()                              build_cnn()  (pure jax)
    train_fn = synk.function(...)            synk.function(...)
    synk.distribute()                        synk.distribute(params)
    synk.data(X), synk.data(y)               synk.data(X), synk.data(y)
    train_fn(X, y, batch=idxs)               train_fn(X, y, params, batch=idxs)
    synk.all_reduce(params, op='avg')        synk.all_reduce(params, 'avg')

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/synk_sgd.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as synk

synk.fork()

# ---- build_cnn(): a small conv net on 16x16 synthetic images ----------
def build_cnn(key):
    ks = jax.random.split(key, 3)
    return {
        "conv": jax.random.normal(ks[0], (8, 1, 3, 3)) * 0.3,
        "w1": jax.random.normal(ks[1], (8 * 8 * 8, 64)) * 0.05,
        "w2": jax.random.normal(ks[2], (64, 10)) * 0.1,
    }


def forward(p, x):
    x = jax.lax.conv_general_dilated(x, p["conv"], (1, 1), "SAME")
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(x @ p["w1"]) @ p["w2"]


# ---- setup_training(): per-worker gradient step (updates stay LOCAL) --
LR = 0.05


def train_fn_serial(x, y, params):
    def loss(p):
        logp = jax.nn.log_softmax(forward(p, x))
        return -jnp.mean(jnp.sum(jax.nn.one_hot(y, 10) * logp, -1))
    l, g = jax.value_and_grad(loss)(params)
    new_params = jax.tree.map(lambda p, g: p - LR * g, params, g)
    return l, new_params


# ---- the Synkhronos program (paper Fig. 5) -----------------------------
train_fn = synk.function(
    train_fn_serial,
    inputs=[synk.Scatter(), synk.Scatter(), synk.Broadcast()],
    outputs=(synk.Reduce("mean"), synk.Reduce(None)),  # params stay per-worker
)

rng = np.random.default_rng(0)
X = rng.normal(size=(2048, 1, 16, 16)).astype(np.float32)
labels = rng.integers(0, 10, size=(2048,)).astype(np.int32)
X += labels[:, None, None, None] * 0.6      # class-dependent shift: learnable
X_train, y_train = synk.data(X), synk.data(labels)

key = jax.random.PRNGKey(0)
params = build_cnn(key)
params_local = synk.distribute(params)      # replicate on every worker

num_epochs, batch = 10, 256
for epoch in range(num_epochs):
    order = rng.permutation(len(X_train))
    for i in range(0, len(order), batch):
        idxs = order[i:i + batch]
        host_params = synk.get_value(params_local, 0)
        loss, new_local = train_fn(X_train, y_train, host_params, batch=idxs)
        # per-worker local updates -> one all-reduce(avg), as in the paper
        # (with plain SGD this preserves the serial algorithm exactly):
        params_local = synk.all_reduce(synk.LocalValues(new_local), "avg")
    print(f"epoch {epoch}: loss {float(loss):.4f}")

final = synk.as_replicated(params_local, check=False)
pred = np.asarray(jnp.argmax(forward(jax.tree.map(jnp.asarray, final), jnp.asarray(X[:256])), -1))
acc = float((pred == labels[:256]).mean())
print(f"train accuracy: {acc:.3f}")
assert acc > 0.4
print("OK")
