"""End-to-end LM training driver: build an architecture from the config
registry, train on the synthetic pipeline with checkpoint/restart, report
loss curve.

Defaults are CPU-sized (a ~1M-param smollm-family model, 200 steps,
loss must drop).  ``--arch <id> --full`` selects the full published
config (for real accelerators); ``--params-100m`` picks a ~100M-param
width for the train-100M-for-a-few-hundred-steps scenario.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import os

import repro.core as synk  # noqa: F401  (mesh init side effects not needed)
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import single_device_mesh
from repro.models.common import ShardRules
from repro.optim import OptConfig
from repro.train import LoopConfig, TrainSettings, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--slices", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs accelerators)")
    ap.add_argument("--params-100m", action="store_true",
                    help="~100M-param config of the same family")
    engine = ap.add_mutually_exclusive_group()
    engine.add_argument("--faithful", action="store_true",
                        help="paper Appendix-A program: data-parallel mesh over "
                             "all devices, bucketed flat all-reduce + fused Adam")
    engine.add_argument("--zero", action="store_true",
                        help="ZeRO flat engine: reduce-scatter + sharded flat Adam")
    ap.add_argument("--bucket-mb", type=float, default=4.0,
                    help="flat-gradient bucket size (MiB)")
    args = ap.parse_args()

    if args.full:
        cfg = get_config(args.arch)
    elif args.params_100m:
        base = get_config(args.arch)
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=2048,
            vocab=32_000, name=base.name + "-100m",
        )
    else:
        cfg = get_smoke_config(args.arch)

    if args.faithful or args.zero:
        from repro.launch.mesh import local_mesh
        mesh = local_mesh(model=1)       # pure DP over every local device
        rules = ShardRules.for_mesh(mesh, faithful=args.faithful)
        settings = TrainSettings(
            num_slices=args.slices, faithful=args.faithful,
            flat_engine="zero" if args.zero else "auto",
        )
    else:
        mesh = single_device_mesh()
        rules = ShardRules.for_mesh(mesh)
        settings = TrainSettings(num_slices=args.slices)
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    res = train(
        cfg, shape, mesh, rules,
        OptConfig(kind="adam", lr=args.lr, bucket_mb=args.bucket_mb),
        settings,
        LoopConfig(steps=args.steps, ckpt_every=max(args.steps // 2, 1),
                   ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 10, 1)),
    )
    first, last = res["losses"][0], res["losses"][-1]
    print(f"loss: {first:.4f} -> {last:.4f}")
    assert last < first, "training did not reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
