#!/usr/bin/env bash
# Tier-1 CI: test suite on a forced 8-device host mesh + the overlap and
# serve benchmarks in smoke mode (write BENCH_overlap.json /
# BENCH_serve.json to the repo root).  The serve bench gates on its
# dispatch counters: steady-state decode must show ZERO new executable
# builds after warmup (the AOT cache must not silently start missing).
#
#   scripts/ci.sh             # full run
#   scripts/ci.sh -k buckets  # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== repo hygiene =="
if git ls-files | grep -q '\.pyc$'; then
  echo "FAIL: compiled bytecode is tracked in git:" >&2
  git ls-files | grep '\.pyc$' >&2
  exit 1
fi
echo "  no tracked *.pyc"

echo "== tier-1 suite (8 forced host devices; 200-episode engine fuzz) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  ENGINE_FUZZ_EPISODES="${ENGINE_FUZZ_EPISODES:-200}" \
  CHAOS_FUZZ_EPISODES="${CHAOS_FUZZ_EPISODES:-6}" \
  ROUTER_FUZZ_EPISODES="${ROUTER_FUZZ_EPISODES:-6}" \
  python -m pytest -x -q "$@"

echo "== overlap bench (smoke) =="
python benchmarks/overlap_bench.py --smoke --json BENCH_overlap.json >/dev/null
python - <<'EOF'
import json
rep = json.load(open("BENCH_overlap.json"))
s = rep["step_ms"]
for k in ("monolithic_flat", "bucketed_flat", "zero_flat", "legacy_gspmd"):
    if k in s:
        print(f"  {k:16s} {s[k]['step_ms']:8.2f} ms/step  buckets={s[k]['num_buckets']}")
d = rep["dispatch"]
print(f"  dispatch: cold {d['cold_ms']:.1f} ms, cached {d['cached_us']:.0f} us, "
      f"presharded {d['presharded_us']:.0f} us")
EOF

echo "== serve bench (smoke, 8 forced host devices) =="
python benchmarks/serve_bench.py --smoke --json BENCH_serve.json >/dev/null
python - <<'EOF'
import json, sys
rep = json.load(open("BENCH_serve.json"))
for name, row in rep["modes"].items():
    kv = ""
    if "kv_reserved_bytes" in row:
        kv = (f"  kv {row['kv_peak_used_bytes'] / 2**20:5.1f}"
              f"/{row['kv_reserved_bytes'] / 2**20:5.1f} MiB used/reserved")
    lat = ""
    if "p50_ms_per_token" in row:
        lat = (f"  p50 {row['p50_ms_per_token']:7.1f} ms/tok  "
               f"p99 {row['p99_ms_per_token']:7.1f} ms/tok")
    print(f"  {name:24s} {row['tokens_per_s']:7.1f} tok/s{lat}{kv}")
h = rep["headline"]
print(f"  speedup_vs_static {h['speedup_vs_static']:.2f}x  "
      f"p99_ratio {h['p99_ratio_vs_static']:.2f}  "
      f"steady_builds_delta {h['steady_builds_delta']}  "
      f"all_builds_delta {h['all_steady_builds_delta']}  "
      f"kv_ratio {h['kv_reserved_ratio_paged_vs_slotted']:.2f}")
print(f"  paged_parity {h['paged_greedy_parity']}  "
      f"prefix_parity {h['prefix_greedy_parity']}  "
      f"preempt_parity {h['preempt_greedy_parity']}  "
      f"prefix_hit_rate {h['prefix_cache_hit_rate']:.2f}  "
      f"prefill_ratio {h['prefix_prefill_token_ratio']:.2f}  "
      f"preemptions {h['preemptions_timed']}+{h['parity_check_preemptions']}")
print(f"  recurrent_parity {h['recurrent_greedy_parity']}  "
      f"recurrent_preempt_parity {h['recurrent_preempt_parity']} "
      f"(x{h['recurrent_preemptions']})  "
      f"hybrid_parity {h['hybrid_greedy_parity']}  "
      f"recurrent_builds_delta {h['recurrent_steady_builds_delta']}")
td = rep["modes"]["continuous_tiered"]
print(f"  tiered: restores {h['tiered_restores']}  "
      f"replayed {h['tiered_replayed_tokens']}  "
      f"o_copy {h['tiered_o_copy_resume']}  "
      f"parity {h['tiered_token_parity']}  "
      f"spilled {td['spilled_bytes'] / 2**20:.1f} MiB  "
      f"builds_delta {h['tiered_steady_builds_delta']}")
sp = rep["modes"]["continuous_spec"]
print(f"  spec: parity {h['spec_greedy_parity']}  "
      f"accept_rate {h['spec_acceptance_rate']:.2f}  "
      f"tok/lane-round {h['spec_tokens_per_decode_dispatch']:.2f}  "
      f"accepted {sp['spec_accepted']}  rejected {sp['spec_rejected']}  "
      f"builds_delta {h['spec_steady_builds_delta']}")
print(f"  chaos: faults {h['chaos_faults_fired']}  all_ok {h['chaos_all_ok']}  "
      f"parity {h['chaos_token_parity']}  "
      f"overhead {h['chaos_recovery_overhead']:.2f}x  "
      f"builds_delta {h['chaos_steady_builds_delta']}")
print(f"  router: lost {h['router_requests_lost']}  all_ok {h['router_all_ok']}  "
      f"failover_parity {h['router_failover_parity']}  "
      f"failovers {h['router_failovers']}  migrated {h['router_migrated']}  "
      f"builds_delta {h['router_steady_builds_delta']}")
tr = rep["modes"]["continuous_traced"]
print(f"  traced: overhead {h['traced_overhead_ratio']:.2f}x  "
      f"parity {h['traced_token_parity']}  "
      f"events {tr['trace_events']}  spans {tr['trace_spans']}  "
      f"builds_delta {h['traced_steady_builds_delta']}")
print(f"  slowest AOT builds: " + ", ".join(
      f"{s:.2f}s" for _, s in rep["meta"]["slowest_builds"][:3]) +
      f"  (total {rep['meta']['aot_build_s_total']:.1f}s)")
if h["steady_builds_delta"] != 0:
    sys.exit("FAIL: serve decode built executables after warmup "
             "(AOT dispatch cache regression)")
if h["all_steady_builds_delta"] != 0:
    sys.exit("FAIL: an engine mode built executables after warmup — "
             "prefix/preempt scheduling must dispatch purely from the "
             "prebuilt AOT cache")
if not h["paged_greedy_parity"]:
    sys.exit("FAIL: paged engine diverged from the slotted engine under "
             "greedy decoding")
if not h["prefix_greedy_parity"]:
    sys.exit("FAIL: prefix-cached engine diverged from the slotted engine "
             "under greedy decoding")
if not h["preempt_greedy_parity"]:
    sys.exit("FAIL: preempting engine diverged from the slotted engine "
             "under greedy decoding")
if h["prefix_cache_hit_rate"] <= 0:
    sys.exit("FAIL: shared-prefix workload produced no prefix-cache hits")
if h["prefix_prefill_token_ratio"] >= 0.6:
    sys.exit("FAIL: prefix caching computed >= 0.6x the no-cache prefill "
             "tokens on the shared-prefix workload")
if h["preemptions_timed"] + h["parity_check_preemptions"] <= 0:
    sys.exit("FAIL: the preempt mode never preempted — its parity gate is "
             "vacuous (pool sizing no longer squeezes the lanes)")
paged = rep["modes"]["continuous_paged"]
slotted = rep["modes"]["continuous_fused"]
if paged["kv_reserved_bytes"] >= slotted["kv_reserved_bytes"]:
    sys.exit("FAIL: paged layout did not reserve less KV HBM than the "
             "slotted max_slots*max_len layout")
if not h["recurrent_greedy_parity"]:
    sys.exit("FAIL: the recurrent (ssm/xlstm) slot engine diverged from "
             "generate_static under greedy decoding")
if not h["hybrid_greedy_parity"]:
    sys.exit("FAIL: the hybrid (zamba) slot engine diverged from "
             "generate_static under greedy decoding")
if not h["recurrent_preempt_parity"] or h["recurrent_preemptions"] <= 0:
    sys.exit("FAIL: recurrent preempt-and-requeue resume is not "
             "token-for-token (or the parity drive never preempted)")
if h["recurrent_steady_builds_delta"] != 0:
    sys.exit("FAIL: a recurrent/hybrid engine mode built executables "
             "after warmup (AOT dispatch cache regression)")
if h["tiered_restores"] <= 0:
    sys.exit("FAIL: the tiered mode never restored from the host tier — "
             "its O(copy) gate is vacuous (pool sizing no longer forces "
             "preemptions, or spills are being dropped)")
if not h["tiered_token_parity"]:
    sys.exit("FAIL: host-tier spill/restore changed greedy tokens — "
             "restored lanes must continue bitwise-identically")
if not h["tiered_o_copy_resume"]:
    sys.exit("FAIL: a tier-restored lane replayed decode steps or "
             "re-prefilled its prompt — resume must be O(bytes copied), "
             "not O(generated tokens)")
if h["tiered_steady_builds_delta"] != 0:
    sys.exit("FAIL: the tiered mode built executables after prebuild — "
             "spill/restore transport must ride the AOT cache")
if not h["spec_greedy_parity"]:
    sys.exit("FAIL: speculative decoding changed greedy tokens — the "
             "draft/verify commit rule must be bitwise vs the sequential "
             "engine")
if h["spec_acceptance_rate"] <= 0:
    sys.exit("FAIL: the spec mode accepted no draft tokens — its parity "
             "and speedup gates are vacuous (draft too far from target?)")
if rep["modes"]["continuous_spec"]["spec_rejected"] <= 0:
    sys.exit("FAIL: the spec mode rejected no draft tokens — the "
             "rollback path was never exercised (draft == target?)")
if h["spec_tokens_per_decode_dispatch"] <= 1.0:
    sys.exit("FAIL: spec decode committed <= 1 token per lane-round — "
             "speculation is not paying for its verify dispatches")
if h["spec_steady_builds_delta"] != 0:
    sys.exit("FAIL: the spec mode built executables after prebuild — "
             "draft prefill + verify must ride the AOT cache")
if h["chaos_faults_fired"] <= 0:
    sys.exit("FAIL: the chaos mode injected no faults — its recovery "
             "gates are vacuous (FaultPlan rates/seed no longer fire)")
if not h["chaos_all_ok"]:
    sys.exit("FAIL: a fault-injected request did not recover to status "
             "'ok' (retry/quarantine path regression)")
if not h["chaos_token_parity"]:
    sys.exit("FAIL: fault recovery changed greedy tokens — preempt-and-"
             "replay resume is no longer bitwise")
if h["chaos_steady_builds_delta"] != 0:
    sys.exit("FAIL: fault recovery built new executables — retries must "
             "reuse the prebuilt bucketed programs")
if h["router_requests_lost"] != 0:
    sys.exit("FAIL: the router lost requests across a replica crash — "
             "failover must conserve every submitted request")
if not h["router_all_ok"]:
    sys.exit("FAIL: a request did not finish 'ok' after replica "
             "crash/drain (router failover regression)")
if not h["router_failover_parity"]:
    sys.exit("FAIL: failover changed greedy tokens — the rebuilt resume "
             "on a survivor is no longer bitwise")
if h["router_failovers"] <= 0:
    sys.exit("FAIL: the router mode never failed over — its parity gate "
             "is vacuous (the kill tick no longer strands requests)")
if h["router_steady_builds_delta"] != 0:
    sys.exit("FAIL: the replica fleet built executables after prebuild — "
             "replicas must share one AOT cache")
if not h["traced_token_parity"]:
    sys.exit("FAIL: arming the observer changed greedy tokens — tracing "
             "must be a pure host-side observer")
if not tr["decode_steps_match"]:
    sys.exit("FAIL: the traced drive took a different number of decode "
             "steps than the untraced drive — tracing perturbed "
             "scheduling")
if h["traced_overhead_ratio"] < 0.95:
    sys.exit(f"FAIL: tracing cost {h['traced_overhead_ratio']:.3f}x of "
             "the untraced decode rate (< 0.95 floor) — an emit path is "
             "doing more than a ring-buffer append (host sync?)")
if h["traced_steady_builds_delta"] != 0:
    sys.exit("FAIL: the traced drive built executables after prebuild — "
             "observability must not change executable keys")
if "metrics" not in tr or tr["metrics"].get("decode_steps", {}).get("value", 0) <= 0:
    sys.exit("FAIL: the traced mode's embedded metrics snapshot is "
             "missing or has no decode_steps counter")
EOF

echo "== trace artifact check =="
python - <<'EOF'
import json, sys
sys.path.insert(0, "src")
from repro.obs import load_jsonl, validate
ev = load_jsonl("BENCH_serve_trace.jsonl")
info = validate(ev)   # spans balance, timelines terminal-complete
chrome = json.load(open("BENCH_serve_trace.json"))
if not chrome.get("traceEvents"):
    sys.exit("FAIL: BENCH_serve_trace.json has no traceEvents")
print(f"  {info['events']} events / {info['spans']} spans / "
      f"{info['requests']} requests / {info['terminals']} terminals; "
      f"chrome trace {len(chrome['traceEvents'])} entries")
EOF

echo "== docs link check =="
python - <<'EOF'
import os, re, sys
paths = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir("docs") if f.endswith(".md"))
bad = []
for path in paths:
    base = os.path.dirname(path)
    text = open(path).read()
    for m in re.finditer(r"\[[^\]]*\]\(([^)\s#]+)(#[^)]*)?\)", text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            bad.append(f"{path}: {target}")
if bad:
    sys.exit("FAIL: broken relative links:\n  " + "\n  ".join(bad))
print(f"  {len(paths)} files, all relative links resolve")
EOF
echo "CI OK — BENCH_overlap.json + BENCH_serve.json written"
