#!/usr/bin/env bash
# Tier-1 CI: test suite on a forced 8-device host mesh + the overlap
# benchmark in smoke mode (writes BENCH_overlap.json to the repo root).
#
#   scripts/ci.sh             # full run
#   scripts/ci.sh -k buckets  # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 suite (8 forced host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" python -m pytest -x -q "$@"

echo "== overlap bench (smoke) =="
python benchmarks/overlap_bench.py --smoke --json BENCH_overlap.json >/dev/null
python - <<'EOF'
import json
rep = json.load(open("BENCH_overlap.json"))
s = rep["step_ms"]
for k in ("monolithic_flat", "bucketed_flat", "zero_flat", "legacy_gspmd"):
    if k in s:
        print(f"  {k:16s} {s[k]['step_ms']:8.2f} ms/step  buckets={s[k]['num_buckets']}")
d = rep["dispatch"]
print(f"  dispatch: cold {d['cold_ms']:.1f} ms, cached {d['cached_us']:.0f} us, "
      f"presharded {d['presharded_us']:.0f} us")
EOF
echo "CI OK — BENCH_overlap.json written"
