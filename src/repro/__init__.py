"""repro: Synkhronos-in-JAX — multi-pod data-parallel function framework."""

__version__ = "1.0.0"
