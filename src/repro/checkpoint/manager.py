"""Fault-tolerant checkpointing.

* Atomic: write to ``<dir>/.tmp-<step>`` then ``rename`` — a crash mid-save
  never corrupts the latest checkpoint.
* keep_k: bounded disk usage.
* Async: saves can run on a background thread so the train loop only pays
  the device->host transfer (double-buffered on host).
* Retry: transient save I/O errors (NFS blips, momentary ENOSPC) retry
  with exponential backoff (bounded, injectable sleep) before surfacing —
  a blip during async persistence doesn't become a hard failure at the
  next ``wait()``.
* Elastic restore: checkpoints are mesh-agnostic host arrays; ``restore``
  re-shards onto whatever mesh/rules the new job runs with — the recovery
  path after losing a pod (restore a 512-chip run onto 256 chips).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import numpy as np


def _flatten_with_names(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[name] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = flat[name]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {name!r}: checkpoint {arr.shape} != model {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3, *,
                 save_retries: int = 3, retry_backoff_s: float = 0.05,
                 sleep: Callable[[float], None] = time.sleep,
                 obs=None):
        if save_retries < 1:
            raise ValueError("save_retries must be >= 1")
        self.dir = directory
        self.keep_k = keep_k
        # optional repro.obs.Observer: checkpoint failures (retry
        # exhaustion, async-save errors surfaced at wait()) dump the
        # flight recorder so the events leading up to the failed save are
        # on disk next to the error
        self.obs = obs
        # bounded retry around transient save I/O: attempt save_retries
        # times total, backing off retry_backoff_s * 2**attempt between
        # tries.  ``sleep`` is injectable so tests don't wait in real time.
        self.save_retries = save_retries
        self.retry_backoff_s = retry_backoff_s
        self._sleep = sleep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        # a failed async _write parks its exception here; wait() (and so
        # the next save()) re-raises it instead of letting the trainer
        # believe the checkpoint exists
        self._error: BaseException | None = None
        # a .tmp-<step> dir is a save that died before its atomic rename:
        # never restorable, only wasted disk — sweep on init
        for d in os.listdir(directory):
            if d.startswith(".tmp-"):
                shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: dict, *, blocking: bool = True,
             extra_meta: dict | None = None) -> None:
        """state: {"params": tree, "opt": tree, ...} (device or host arrays)."""
        self.wait()   # never two writers at once (same-step dir races)
        host = {k: _flatten_with_names(v) for k, v in state.items()}
        meta = {"step": step, "groups": {k: sorted(v) for k, v in host.items()}}
        if extra_meta:
            meta.update(extra_meta)
        if blocking:
            self._write(step, host, meta)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta),
                daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        """Join any in-flight async save; re-raise its failure if it had
        one (a daemon thread's exception is otherwise silently lost)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            if self.obs is not None:
                self.obs.record("ckpt_async_failure", error=repr(err))
                self.obs.dump("checkpoint_async_save_failed",
                              context={"dir": self.dir, "error": repr(err)})
            raise RuntimeError("async checkpoint save failed") from err

    def _write_guarded(self, step: int, host: dict, meta: dict) -> None:
        try:
            self._write(step, host, meta)
        except BaseException as e:  # noqa: BLE001 - surfaced at wait()
            self._error = e

    def _write(self, step: int, host: dict, meta: dict) -> None:
        """One save, retried through transient ``OSError``s.  Each
        attempt restarts from the tmp dir (``_write_once`` resets it), so
        a half-written attempt never leaks into the renamed checkpoint;
        after the last attempt the error propagates (and the orphaned
        tmp dir is left for the init-time sweep, as before)."""
        for attempt in range(self.save_retries):
            try:
                return self._write_once(step, host, meta)
            except OSError as e:
                if attempt + 1 >= self.save_retries:
                    if self.obs is not None:
                        self.obs.record("ckpt_retry_exhausted", step=step,
                                        attempts=self.save_retries,
                                        error=repr(e))
                        self.obs.dump("checkpoint_save_retries_exhausted",
                                      context={"dir": self.dir, "step": step,
                                               "attempts": self.save_retries,
                                               "error": repr(e)})
                    raise
                self._sleep(self.retry_backoff_s * 2 ** attempt)

    def _write_once(self, step: int, host: dict, meta: dict) -> None:
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for group, flat in host.items():
            np.savez(os.path.join(tmp, f"{group}.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_meta(self, step: int | None = None) -> tuple[int, dict]:
        """Read a checkpoint's ``meta.json`` (latest when ``step`` is
        None) without touching its array groups — the host-state side
        channel ``save(extra_meta=...)`` rides (engine snapshots, flat-
        optimizer layout).  Returns ``(step, meta)``."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return meta["step"], meta

    def restore(self, template: dict, step: int | None = None,
                shard_fn: Callable[[Any], Any] | None = None) -> tuple[int, dict]:
        """Restore into the structure of ``template``.

        ``shard_fn(tree) -> tree`` re-shards host arrays onto the current
        mesh (elastic restore); identity if omitted.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        state = {}
        for group, tmpl in template.items():
            with np.load(os.path.join(path, f"{group}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            tree = _unflatten_like(tmpl, flat)
            state[group] = shard_fn(tree) if shard_fn else tree
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return meta["step"], state
