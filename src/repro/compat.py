"""Version-tolerance shims over the JAX API surface this repo uses.

The repo targets the modern JAX API (``jax.shard_map``, ``jax.sharding.
AxisType``, ``jax.make_mesh(..., axis_types=...)``, ``jax.lax.pvary``); the
installed JAX may predate any of these.  Every call site imports the symbol
from here instead of guessing, so the whole version policy lives in one
module:

* ``AxisType``       — ``jax.sharding.AxisType`` or an equivalent stub enum.
* ``make_mesh``      — forwards ``axis_types=`` only when supported.
* ``shard_map``      — ``jax.shard_map`` or ``jax.experimental.shard_map``;
                       normalizes the replication-check kwarg (``check_vma``
                       on new JAX, ``check_rep`` on old).
* ``pvary``          — identity on JAX versions without varying-manual-axes
                       tracking (there, carries need no explicit pvary).
* ``psum_scatter``   — re-export (present in every supported version; named
                       here so collective call sites read uniformly).
"""
from __future__ import annotations

import enum
import inspect
from typing import Any

import jax

# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

try:  # JAX >= 0.5-era explicit-sharding API
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # older JAX: only Auto semantics exist
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

_MAKE_MESH_TAKES_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(shape, axes, *, axis_types=None, devices=None):
    """``jax.make_mesh`` dropping ``axis_types`` when unsupported (old JAX
    treats every axis as Auto, which is exactly what the dropped argument
    would have requested)."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _MAKE_MESH_TAKES_AXIS_TYPES and axis_types is not None:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = (
        "check_vma"
        if "check_vma" in inspect.signature(jax.shard_map).parameters
        else "check_rep"
    )
else:  # JAX <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Uniform entry point: new-JAX ``check_vma`` semantics, mapped onto
    ``check_rep`` for old JAX (both disable replication/varying-axes
    checking when False, which is how this repo always calls it)."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )


# ---------------------------------------------------------------------------
# pvary
# ---------------------------------------------------------------------------

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:
    def pvary(x, axis_name):  # noqa: ARG001 - signature parity
        """No varying-manual-axes tracking on this JAX: identity."""
        return x


psum_scatter = jax.lax.psum_scatter


# ---------------------------------------------------------------------------
# optimization_barrier
# ---------------------------------------------------------------------------
# Old JAX has no differentiation rule for optimization_barrier; wrap it in a
# custom_jvp that barriers the primal and passes tangents through (the
# barrier is a scheduling hint, not a math op, so this is exact).

@jax.custom_jvp
def optimization_barrier(x):
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return optimization_barrier(x), t
