from .base import (
    ARCH_IDS,
    ArchConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    get_smoke_config,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS", "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "SHAPES", "get_config", "get_smoke_config", "shape_applicable",
]
