"""Architecture configuration schema + the assigned input-shape sets.

One ``<arch>.py`` per assigned architecture lives next to this module; each
exports ``CONFIG`` (the exact published configuration) and ``SMOKE``
(a reduced same-family configuration for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

ARCH_IDS = (
    "deepseek-67b",
    "smollm-360m",
    "stablelm-12b",
    "gemma2-27b",
    "internvl2-76b",
    "zamba2-1.2b",
    "whisper-tiny",
    "xlstm-1.3b",
    "qwen3-moe-30b-a3b",
    "qwen3-moe-235b-a22b",
)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0          # per-expert FFN hidden dim
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 0             # N, SSM state size
    head_dim: int = 64         # P, channels per SSD head
    expand: int = 2            # d_inner = expand * d_model
    n_groups: int = 1          # B/C parameter groups
    conv_kernel: int = 4
    chunk: int = 256           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0            # 0 -> d_model // n_heads

    # attention features
    window: int = 0            # >0: sliding-window size for local layers
    alt_local_global: bool = False   # gemma2: even layers local, odd global
    attn_softcap: float = 0.0        # gemma2 attention-logit softcap
    logit_softcap: float = 0.0       # gemma2 final-logit softcap
    rope_theta: float = 10_000.0
    qk_norm: bool = False            # qwen3 QK-RMSNorm
    query_scale: float = 0.0         # 0 -> head_dim**-0.5 (gemma2 overrides)
    gate_act: str = "silu"           # ffn gate activation ("silu" | "gelu")
    attn_impl: str = "chunked"       # "chunked" (XLA) | "pallas" (TPU kernel)

    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()

    # hybrid / xlstm block layout
    attn_every: int = 0        # zamba2: shared attn block every k SSM layers
    slstm_every: int = 0       # xlstm: one sLSTM per k blocks (rest mLSTM)

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0           # encoder frame count (stubbed frontend)

    # modality frontend stubs (vlm/audio): precomputed embeddings
    frontend_tokens: int = 0   # image patch tokens prepended to the sequence
    frontend_dim: int = 0      # stub embedding dim (projected to d_model)

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # set True if the arch supports O(seq) decode (SSM/hybrid/linear-attn)
    subquadratic: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_params_dense(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        d, f, v, h = self.d_model, self.d_ff, self.vocab, self.head_dim
        attn = d * h * (self.n_heads + 2 * self.n_kv) + self.n_heads * h * d
        ffn = 3 * d * f if f else 0
        if self.moe.num_experts:
            ffn = 3 * d * self.moe.d_expert * self.moe.num_experts
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb

    @property
    def n_params_active(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.moe.num_experts:
            return self.n_params_dense
        d, v = self.d_model, self.vocab
        h = self.head_dim
        attn = d * h * (self.n_heads + 2 * self.n_kv) + self.n_heads * h * d
        ffn = 3 * d * self.moe.d_expert * self.moe.top_k
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a shape cell runs for this arch (skips noted in DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; skipped for full-attention archs"
    return True, ""


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.SMOKE
