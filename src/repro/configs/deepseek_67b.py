"""DeepSeek-67B — dense llama-arch [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=22016,
    vocab=102_400,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="deepseek-67b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=160,
    vocab=256,
)
