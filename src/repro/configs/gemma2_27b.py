"""Gemma-2-27B — dense with local/global alternating attention and logit
softcapping [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128,
window 4096 on local layers, attn softcap 50, final-logit softcap 30.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv=16,
    d_head=128,
    d_ff=36_864,
    vocab=256_000,
    window=4096,
    alt_local_global=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = d_model/n_heads
    gate_act="gelu",                   # GeGLU
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="gemma2-27b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=192,
    vocab=256,
    window=16,
    alt_local_global=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
)
