"""InternVL2-76B — VLM: InternViT frontend + LLM backbone [arXiv:2404.16821].

Backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed patch embeddings (frontend_tokens x frontend_dim) which the
model projects into d_model and prepends to the token sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=28_672,
    vocab=128_256,
    frontend_tokens=256,
    frontend_dim=3200,       # InternViT-6B hidden size
)

SMOKE = ArchConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=192,
    vocab=256,
    frontend_tokens=4,
    frontend_dim=24,
)
