"""Qwen3-235B-A22B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B].

94L d_model=4096 64H (GQA kv=4, head_dim=128, QK-norm) d_expert=1536
vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv=4,
    d_head=128,
    d_ff=0,
    vocab=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536, capacity_factor=1.25),
    # 235B params: bf16 storage (fp32 Adam moments act as the master copy)
    # is what makes params+grads+states fit 16 GB/chip at 256 chips.
    param_dtype="bfloat16",
)

SMOKE = ArchConfig(
    name="qwen3-moe-235b-a22b-smoke",
    family="moe",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv=1,
    d_head=16,
    d_ff=0,
    vocab=256,
    qk_norm=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=24, capacity_factor=1.5),
)
