"""Qwen3-30B-A3B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4, head_dim=128, QK-norm) d_expert=768
vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    d_head=128,
    d_ff=0,
    vocab=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768, capacity_factor=1.25),
)

SMOKE = ArchConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=0,
    vocab=256,
    qk_norm=True,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=1.5),
)
