"""SmolLM-360M — small dense llama-arch [hf:HuggingFaceTB/SmolLM].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.  Tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv=5,
    d_ff=2560,
    vocab=49_152,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="smollm-360m-smoke",
    family="dense",
    n_layers=3,
    d_model=48,
    n_heads=3,
    n_kv=1,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
)
