"""StableLM-2-12B — dense [hf:stabilityai/stablelm-2-12b].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    d_ff=13_824,
    vocab=100_352,
)

SMOKE = ArchConfig(
    name="stablelm-12b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=192,
    vocab=256,
)
