"""Whisper-tiny — encoder-decoder audio transformer [arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536 vocab=51865.  The conv
frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed frame embeddings (enc_seq x d_model, i.e. post-conv features).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,          # decoder layers
    enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51_865,
    frontend_dim=384,    # frame embeddings arrive at model width (post-conv stub)
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="whisper-tiny-smoke",
    family="audio",
    n_layers=2,
    enc_layers=2,
    enc_seq=16,
    d_model=48,
    n_heads=3,
    n_kv=3,
    d_ff=96,
    vocab=256,
    frontend_dim=48,
    tie_embeddings=True,
)
