"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.  Block ratio mLSTM:sLSTM =
7:1 (one sLSTM per ``slstm_every`` blocks).  d_ff=0: xLSTM blocks carry
their own up/down projections instead of a separate FFN.  Linear
recurrence: eligible for long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50_304,
    slstm_every=8,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv=2,
    d_ff=0,
    vocab=256,
    slstm_every=2,
    subquadratic=True,
)
