"""Zamba2-1.2B — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

38L d_model=2048 32H (kv=32, MHA shared block) d_ff=8192 vocab=32000,
ssm_state=64.  A single shared transformer (attn+MLP) block is applied
every ``attn_every`` Mamba2 layers, taking concat(hidden, embedding) as
input (Zamba's global skip).  Sub-quadratic: eligible for long_500k.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32_000,
    ssm=SSMConfig(state=64, head_dim=64, expand=2, n_groups=1, conv_kernel=4, chunk=256),
    attn_every=6,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    ssm=SSMConfig(state=16, head_dim=16, expand=2, n_groups=1, conv_kernel=4, chunk=8),
    attn_every=2,
    subquadratic=True,
)
