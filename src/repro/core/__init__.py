"""Synkhronos-JAX core: data parallelism at the level of individual functions.

Public API (mirrors the paper's, Appendix A):

    import repro.core as synk

    ctx = synk.fork()                       # build the device mesh
    f = synk.function(fn, inputs=[synk.Scatter(), synk.Scatter()],
                      outputs=synk.Reduce("mean"))
    params = synk.distribute(params)        # replicate shared state
    out = f(x, y)                           # scatter -> compute -> reduce
    out = f(x, y, num_slices=4)             # §5.1 input slicing
    out = f(dx, dy, batch=idxs)             # §5.2 input indexing
    params = synk.all_reduce(params, "avg") # NCCL-style collective
"""
from .aot import AotCache
from .context import SynkContext, current, fork, make_mesh, reset
from .specs import Broadcast, Reduce, Scatter
from .function import SynkFunction, function
from .data import DeviceDataset, SynkData, data, scatter_data
from .collectives import (
    LocalValues,
    all_reduce,
    as_replicated,
    broadcast,
    distribute,
    gather,
    get_value,
    reduce_to,
    replicate,
    scatter_shared,
    set_value,
)

__all__ = [
    "AotCache",
    "SynkContext", "current", "fork", "make_mesh", "reset",
    "Broadcast", "Reduce", "Scatter",
    "SynkFunction", "function",
    "DeviceDataset", "SynkData", "data", "scatter_data",
    "LocalValues", "all_reduce", "as_replicated", "broadcast", "distribute",
    "gather", "get_value", "reduce_to", "replicate", "scatter_shared",
    "set_value",
]
