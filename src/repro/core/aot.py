"""AOT executable cache shared by the train and serve dispatch paths.

``SynkFunction`` (core/function.py) caches one ``.lower().compile()``'d
executable per call signature so steady-state dispatch is a dict probe.
The serve engine needs exactly the same machinery for its prefill/decode
executables — keyed on (config, bucketed prompt length, slot count)
instead of argument signatures — so the cache lives here as a small
reusable class instead of inline in ``SynkFunction.__call__``.

The cache is deliberately dumb: a dict from a hashable key to whatever
``build()`` returned, plus hit/miss counters.  Callers own key hygiene
(include every static option that changes the lowered program) and
eviction (none — executables are meant to live for the process; an
unbounded signature space is a caller bug, surfaced by ``builds``
growing without bound).
"""
from __future__ import annotations

from typing import Any, Callable, Iterator


class AotCache:
    """Keyed store of AOT-compiled executables with hit/miss counters.

    ``stats["builds"]`` counts cache misses (one trace+compile each);
    ``stats["cache_hits"]`` counts steady-state dispatches.  A warmed-up
    caller must show a flat ``builds`` counter — CI asserts this for the
    serve engine (scripts/ci.sh) and the overlap bench tracks it for
    ``SynkFunction``.

    Invariants: ``builds == len(self)`` (every miss stores exactly one
    entry, nothing is ever evicted); ``builds + cache_hits`` == total
    ``get`` calls; a key's entry is immutable once stored (``get`` never
    re-runs ``build`` for a present key, so sharing one cache across
    engines/benches can never recompile behind a caller's back).
    """

    def __init__(self, name: str = "aot"):
        self.name = name
        self._entries: dict[Any, Any] = {}
        self.stats = {"builds": 0, "cache_hits": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def keys(self) -> Iterator[Any]:
        return iter(self._entries)

    def get(self, key, build: Callable[[], Any]):
        """Return the cached entry for ``key``, building it on first use."""
        entry = self._entries.get(key)
        if entry is None:
            self.stats["builds"] += 1
            entry = build()
            self._entries[key] = entry
        else:
            self.stats["cache_hits"] += 1
        return entry
