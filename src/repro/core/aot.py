"""AOT executable cache shared by the train and serve dispatch paths.

``SynkFunction`` (core/function.py) caches one ``.lower().compile()``'d
executable per call signature so steady-state dispatch is a dict probe.
The serve engine needs exactly the same machinery for its prefill/decode
executables — keyed on (config, bucketed prompt length, slot count)
instead of argument signatures — so the cache lives here as a small
reusable class instead of inline in ``SynkFunction.__call__``.

The cache is deliberately dumb: a dict from a hashable key to whatever
``build()`` returned, plus hit/miss counters.  Callers own key hygiene
(include every static option that changes the lowered program) and
eviction (none — executables are meant to live for the process; an
unbounded signature space is a caller bug, surfaced by ``builds``
growing without bound).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Iterator


class AotCache:
    """Keyed store of AOT-compiled executables with hit/miss counters.

    ``stats["builds"]`` counts cache misses (one trace+compile each);
    ``stats["cache_hits"]`` counts steady-state dispatches.  A warmed-up
    caller must show a flat ``builds`` counter — CI asserts this for the
    serve engine (scripts/ci.sh) and the overlap bench tracks it for
    ``SynkFunction``.

    Every miss also records its lower+compile wall seconds in
    ``build_seconds`` (always wall time, even when the owning engine runs
    on a fake clock — compile cost is a real-world budget), and emits an
    ``aot_build`` trace span when an ``obs`` handle is attached; see
    ``top_builds`` for the slowest-builds report the serve bench embeds.

    Invariants: ``builds == len(self)`` (every miss stores exactly one
    entry, nothing is ever evicted); ``builds + cache_hits`` == total
    ``get`` calls; a key's entry is immutable once stored (``get`` never
    re-runs ``build`` for a present key, so sharing one cache across
    engines/benches can never recompile behind a caller's back).
    """

    def __init__(self, name: str = "aot", *, obs=None):
        self.name = name
        self._entries: dict[Any, Any] = {}
        self.stats = {"builds": 0, "cache_hits": 0}
        self.build_seconds: dict[Any, float] = {}
        self.obs = obs

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def keys(self) -> Iterator[Any]:
        return iter(self._entries)

    def get(self, key, build: Callable[[], Any]):
        """Return the cached entry for ``key``, building it on first use."""
        entry = self._entries.get(key)
        if entry is None:
            self.stats["builds"] += 1
            sid = None if self.obs is None else self.obs.begin(
                "aot_build", cat="aot", track=self.name, key=str(key))
            t0 = time.perf_counter()
            entry = build()
            self.build_seconds[key] = time.perf_counter() - t0
            if self.obs is not None:
                self.obs.end(sid)
            self._entries[key] = entry
        else:
            self.stats["cache_hits"] += 1
        return entry

    @property
    def build_s_total(self) -> float:
        return sum(self.build_seconds.values())

    def top_builds(self, n: int = 5) -> list[tuple[str, float]]:
        """The ``n`` slowest builds as (str(key), seconds), slowest first."""
        ranked = sorted(self.build_seconds.items(),
                        key=lambda kv: kv[1], reverse=True)
        return [(str(k), round(s, 4)) for k, s in ranked[:n]]
