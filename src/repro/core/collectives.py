"""MPI-like collectives over replicated/per-worker state (paper §3.2).

The paper manages one copy of every Theano shared variable per GPU and
exposes NCCL collectives (broadcast, all-reduce, scatter, gather) plus
get/set on individual devices.  The JAX analogue distinguishes two layouts:

* **Replicated state** (a plain pytree with replicated sharding): under
  SPMD there is one logical copy, so ``broadcast`` is ``distribute`` and
  ``all_reduce`` is the identity.  Used by the ``gspmd`` path.

* **Per-worker state** (:class:`LocalValues`): arrays with an explicit
  leading worker axis sharded over the data axes — the honest encoding of
  the paper's "updates are applied only locally within each GPU".  The
  collectives below reproduce NCCL semantics across that axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from . import context as ctx_mod

_OPS = ("avg", "mean", "sum", "max", "min", "prod")


@dataclasses.dataclass
class LocalValues:
    """A pytree with one value per data-parallel worker.

    Every leaf has leading dim == n_workers, sharded over the data axes, so
    worker *i*'s copy lives in worker *i*'s memory — the paper's replicated
    shared variables.
    """

    tree: Any

    def local(self, fn_tree=None):
        return self.tree


def distribute(tree: Any, ctx: ctx_mod.SynkContext | None = None) -> LocalValues:
    """Paper's ``synk.distribute()``: replicate state onto every worker.

    Returns per-worker copies (LocalValues) so that subsequent local updates
    may diverge, exactly as Theano shared variables replicated per GPU do.
    """
    ctx = ctx or ctx_mod.current()
    n = ctx.n_data

    def rep(x):
        x = jnp.asarray(x)
        stacked = jnp.broadcast_to(x[None], (n,) + x.shape)
        return jax.device_put(stacked, ctx.sharding(ctx.data_spec(*([None] * x.ndim))))

    return LocalValues(jax.tree.map(rep, tree))


def replicate(tree: Any, ctx: ctx_mod.SynkContext | None = None) -> Any:
    """Single-copy replication (gspmd path): one logical array, replicated
    sharding across the whole mesh."""
    ctx = ctx or ctx_mod.current()
    return jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), ctx.sharding(P())), tree)


# ---------------------------------------------------------------------------
# NCCL-style collectives over LocalValues
# ---------------------------------------------------------------------------

def _shard_mapped(op_fn, ctx: ctx_mod.SynkContext):
    daxes = ctx.data_axes

    def per_leaf(x):
        spec = P(daxes, *([None] * (x.ndim - 1)))

        def dev(v):
            # v: (1, ...) local block
            return op_fn(v, daxes)

        return jax.jit(
            compat.shard_map(dev, mesh=ctx.mesh, in_specs=spec, out_specs=spec)
        )(x)

    return per_leaf


def all_reduce(values: LocalValues, op: str = "avg", ctx=None) -> LocalValues:
    """Paper's ``synk.all_reduce``: combine all workers' copies in place.

    After this call every worker holds the reduced value (NCCL all-reduce).
    """
    ctx = ctx or ctx_mod.current()
    if op not in _OPS:
        raise ValueError(f"op {op!r} not in {_OPS}")

    def op_fn(v, daxes):
        if op in ("avg", "mean"):
            return jax.lax.pmean(v, daxes)
        if op == "sum":
            return jax.lax.psum(v, daxes)
        if op == "max":
            return jax.lax.pmax(v, daxes)
        if op == "min":
            return jax.lax.pmin(v, daxes)
        if op == "prod":
            return jnp.exp(jax.lax.psum(jnp.log(v), daxes))
        raise AssertionError(op)

    f = _shard_mapped(op_fn, ctx)
    return LocalValues(jax.tree.map(f, values.tree))


def broadcast(values: LocalValues, root: int = 0, ctx=None) -> LocalValues:
    """NCCL broadcast: overwrite all workers' copies with ``root``'s."""
    ctx = ctx or ctx_mod.current()

    def per_leaf(x):
        src = x[root]
        n = x.shape[0]
        stacked = jnp.broadcast_to(src[None], (n,) + src.shape)
        return jax.device_put(
            stacked, ctx.sharding(ctx.data_spec(*([None] * src.ndim)))
        )

    return LocalValues(jax.tree.map(per_leaf, values.tree))


def reduce_to(values: LocalValues, op: str = "avg", root: int = 0, ctx=None) -> Any:
    """NCCL reduce: combine copies, return the (host-visible) root value."""
    red = all_reduce(values, op=op, ctx=ctx)
    return jax.tree.map(lambda x: x[root], red.tree)


def gather(values: LocalValues, ctx=None) -> Any:
    """Gather per-worker copies to the master (host): leading worker axis."""
    return jax.tree.map(np.asarray, values.tree)


def get_value(values: LocalValues, rank: int) -> Any:
    """Paper: 'get ... values on any individual GPU'."""
    return jax.tree.map(lambda x: np.asarray(x[rank]), values.tree)


def set_value(values: LocalValues, rank: int, new: Any) -> LocalValues:
    """Paper: 'set values on any individual GPU'."""
    def per_leaf(x, v):
        return x.at[rank].set(jnp.asarray(v))

    return LocalValues(jax.tree.map(per_leaf, values.tree, new))


def scatter_shared(tree: Any, ctx=None) -> LocalValues:
    """Paper §4.2: split arrays by first axis into per-worker shared state."""
    ctx = ctx or ctx_mod.current()
    n = ctx.n_data

    def per_leaf(x):
        x = jnp.asarray(x)
        if x.shape[0] % n != 0:
            raise ValueError(
                f"scatter_shared: leading dim {x.shape[0]} not divisible by {n}"
            )
        y = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        return jax.device_put(
            y, ctx.sharding(ctx.data_spec(*([None] * (y.ndim - 1))))
        )

    return LocalValues(jax.tree.map(per_leaf, tree))


def as_replicated(values: LocalValues, check: bool = True) -> Any:
    """Collapse per-worker copies to one logical tree (after an all-reduce
    or broadcast made them identical)."""
    def per_leaf(x):
        if check:
            first = x[0]
            if not bool(jnp.all(jnp.isclose(x, first[None]) | ~jnp.isfinite(x) & ~jnp.isfinite(first[None]))):
                raise ValueError("worker copies diverged; all_reduce/broadcast first")
        return x[0]

    return jax.tree.map(per_leaf, values.tree)
