"""Synkhronos execution context: mesh construction and global state.

The paper's ``synk.fork()`` spawned one Python process per GPU and used
barriers for synchronization.  Under XLA SPMD there is a single program and
synchronization is structural, so ``fork`` builds a ``jax.sharding.Mesh``
instead.  The mesh axes play the role of the paper's workers:

* ``data`` axes  — the paper's data-parallel workers (scatter/reduce axes).
* ``model`` axis — tensor/expert/sequence parallel groups (beyond-paper).
* ``pod`` axis   — the outermost data-parallel axis across pods.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

_CURRENT: "SynkContext | None" = None

# Axes that scatter/reduce operate over, in nesting order. Every axis name in
# a mesh that appears in this tuple is treated as data-parallel.
DATA_AXIS_CANDIDATES = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class SynkContext:
    """Holds the mesh and the split between data-parallel and model axes."""

    mesh: Mesh
    data_axes: tuple[str, ...]
    model_axes: tuple[str, ...]

    @property
    def n_data(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes], dtype=np.int64)) if self.data_axes else 1

    @property
    def n_model(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.model_axes], dtype=np.int64)) if self.model_axes else 1

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    def data_spec(self, *trailing: str | None) -> P:
        """PartitionSpec scattering the leading axis over all data axes."""
        return P(self.data_axes, *trailing)

    def replicated_spec(self) -> P:
        return P()

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types (GSPMD propagation)."""
    return compat.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(compat.AxisType.Auto,) * len(axes),
    )


def fork(
    mesh_shape: Sequence[int] | None = None,
    axes: Sequence[str] | None = None,
    *,
    data_axes: Sequence[str] | None = None,
    mesh: Mesh | None = None,
) -> SynkContext:
    """Initialise the Synkhronos context (paper: ``synk.fork()``).

    With no arguments, uses every local device on a single ``data`` axis —
    the direct analogue of the paper's "automatically uses all GPUs".
    """
    global _CURRENT
    if mesh is None:
        if mesh_shape is None:
            n = jax.device_count()
            mesh_shape, axes = (n,), ("data",)
        if axes is None:
            raise ValueError("axes must be given when mesh_shape is")
        mesh = make_mesh(mesh_shape, axes)
    if data_axes is None:
        data_axes = tuple(a for a in mesh.axis_names if a in DATA_AXIS_CANDIDATES)
        if not data_axes:  # single unnamed-purpose mesh: treat every axis as data
            data_axes = tuple(mesh.axis_names)
    model_axes = tuple(a for a in mesh.axis_names if a not in data_axes)
    ctx = SynkContext(mesh=mesh, data_axes=tuple(data_axes), model_axes=model_axes)
    _CURRENT = ctx
    return ctx


def current() -> SynkContext:
    if _CURRENT is None:
        return fork()
    return _CURRENT


def reset() -> None:
    """Drop the global context (tests)."""
    global _CURRENT
    _CURRENT = None
