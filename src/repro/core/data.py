"""Synkhronos data objects (paper §4).

Two storage tiers, mirroring the paper:

* :class:`SynkData` — host-resident arrays (the paper's OS shared memory).
  Numpy-interfaced, over-allocatable so they can grow/shrink without
  reallocation (paper §4.1), excerptable by index lists with no extra
  copies beyond the excerpt itself.

* :class:`DeviceDataset` — device-resident datasets sharded along the
  leading axis across the data-parallel workers (paper §4.2 "scatter"),
  for programs whose inputs are re-used across many function calls.
  ``batch=`` indices are **global** rows of the pre-scatter array; each
  worker gathers on device from its local shard (paper §5.2's on-GPU
  input indexing), routing rows between workers when an index chunk
  crosses shard boundaries.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import context as ctx_mod


class SynkData:
    """Host array with over-allocation, the analogue of paper §4.1 objects.

    The outward-facing numpy view may be smaller than the underlying
    allocation, so growing within capacity never copies.
    """

    def __init__(self, values: np.ndarray, *, oversize: float = 1.0):
        values = np.asarray(values)
        if oversize < 1.0:
            raise ValueError("oversize must be >= 1.0")
        cap = int(math.ceil(values.shape[0] * oversize)) if values.ndim else 1
        self._buffer = np.empty((max(cap, values.shape[0]),) + values.shape[1:], values.dtype)
        self._length = values.shape[0]
        self._buffer[: self._length] = values

    # -- numpy interface -------------------------------------------------
    @property
    def array(self) -> np.ndarray:
        """The outward-facing numpy view (writable, zero-copy)."""
        return self._buffer[: self._length]

    def __array__(self, dtype=None, copy=None):
        a = self.array
        return a.astype(dtype) if dtype is not None else a

    def __getitem__(self, idx):
        return self.array[idx]

    def __setitem__(self, idx, value):
        self.array[idx] = value

    def __len__(self) -> int:
        return self._length

    @property
    def shape(self):
        return self.array.shape

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def capacity(self) -> int:
        return self._buffer.shape[0]

    # -- paper §4.1 special methods ---------------------------------------
    def set_length(self, n: int) -> None:
        """Grow/shrink the outward array; no copy while ``n <= capacity``."""
        if n <= self._buffer.shape[0]:
            self._length = n
            return
        new = np.empty((n,) + self._buffer.shape[1:], self._buffer.dtype)
        new[: self._length] = self._buffer[: self._length]
        self._buffer = new
        self._length = n

    def free(self) -> None:
        """Release the underlying allocation (paper: freeing their memory)."""
        self._buffer = np.empty((0,) + self._buffer.shape[1:], self._buffer.dtype)
        self._length = 0

    def excerpt(self, idx) -> np.ndarray:
        """Materialize ``self[idx]`` — the single copy the paper permits for
        shuffling (each worker excerpts its share in parallel; here the
        excerpt feeds a sharded ``device_put``)."""
        return self.array[idx]


def data(values, *, oversize: float = 1.0) -> SynkData:
    """Paper's ``synk.data(...)`` constructor."""
    return SynkData(np.asarray(values), oversize=oversize)


class DeviceDataset:
    """Dataset scattered across device memories (paper §4.2).

    ``array`` is a global jax.Array sharded along axis 0 over the data
    axes.  ``local_length`` is the per-worker shard length.  Device-side
    indexing (``batch=``) takes **global** row ids in ``[0, len(self))``;
    workers rebase them to shard-local positions (and route rows across
    workers when a chunk references another worker's shard).
    """

    def __init__(self, array: jax.Array, n_shards: int):
        self.array = array
        self.n_shards = n_shards
        if array.shape[0] % n_shards != 0:
            raise ValueError("scattered dataset length must divide the data-parallel size")
        self.local_length = array.shape[0] // n_shards

    @property
    def shape(self):
        return self.array.shape

    @property
    def dtype(self):
        return self.array.dtype

    def __len__(self):
        return self.array.shape[0]


def scatter_data(values, ctx: "ctx_mod.SynkContext | None" = None) -> DeviceDataset:
    """Paper §4.2 'scatter' collective: split an array by its first axis
    into device-resident storage across the data-parallel workers."""
    ctx = ctx or ctx_mod.current()
    values = np.asarray(values) if not isinstance(values, (jax.Array, jnp.ndarray)) else values
    n = ctx.n_data
    if values.shape[0] % n != 0:
        pad = n - values.shape[0] % n  # paper scatters "equally (as possible)"
        reps = np.repeat(values[-1:], pad, axis=0)
        values = np.concatenate([np.asarray(values), reps], axis=0)
    sharding = ctx.sharding(ctx.data_spec(*([None] * (values.ndim - 1))))
    arr = jax.device_put(values, sharding)
    return DeviceDataset(arr, n)


def is_dataset(x: Any) -> bool:
    return isinstance(x, DeviceDataset)


def is_host_data(x: Any) -> bool:
    return isinstance(x, SynkData)
