"""``synk.function`` — data-parallel execution of a serial function.

The user writes a serial ``fn`` over its batch of inputs; calling the
Synkhronos function induces the paper's §3.2 sequence:

  1) data inputs are scattered equally across workers,
  2) each worker calls the same function on its assigned data,
  3) results are reduced or gathered back and returned.

Two backends:

* ``shard_map`` (default, paper-faithful): an explicit per-worker program.
  Each device runs ``fn`` on its shard; outputs are combined with
  ``lax.pmean/psum/pmax/pmin/all_gather`` according to each output's
  :class:`Reduce` spec.  Updates to state are local per worker unless the
  user reduces them — exactly the paper's semantics.

* ``gspmd``: ``jax.jit`` with batch-sharded ``in_shardings``.  Here ``fn``
  is the *global* program and XLA inserts/overlaps collectives.  This is
  the beyond-paper optimized path used by the large-scale trainer.

Both support the paper's §5 extensions: ``num_slices=`` (automated input
slicing with aggregation) and ``batch=`` (input indexing, host- or
device-resident).

Dispatch is cheap: the per-call work is one signature probe over the raw
arguments.  Everything derivable from the signature — per-leaf target
shardings, the traced/compiled executable (AOT ``.lower().compile()``),
the output post-processing — is computed once per (shapes, dtypes,
treedefs, call options) and cached.  ``device_put`` is skipped for arrays
already resident with the target sharding, and ``donate=True`` donates
scattered input buffers to the executable — standard ``donate_argnums``
semantics: pass an already-staged device array to a donating function and
YOUR array is consumed (deleted after the call), exactly as with
``jax.jit``.  Host inputs are staged into fresh buffers each call and are
always safe to donate.

``batch=`` indices into a :class:`DeviceDataset` are **global** row ids
(the dataset's pre-scatter leading axis).  When each scattered index chunk
lands in its own worker's shard (e.g. per-worker shuffles), workers take
rows locally after rebasing to shard-local positions; otherwise rows are
routed between workers with a masked ``psum`` gather (correct for any
permutation, at the cost of one collective over the indexed batch).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from . import context as ctx_mod
from .aot import AotCache
from .data import DeviceDataset, SynkData, is_dataset, is_host_data
from .slicing import _flatten_ops, sliced_call
from .specs import Broadcast, Reduce, Scatter, canonicalize_in_spec, canonicalize_out_spec


@dataclasses.dataclass(frozen=True)
class _CallPlan:
    """Static description of one call signature (cache key companion)."""

    num_slices: int
    indexed: bool                    # batch= indices present
    routed: bool                     # device-resident indices cross shards
    dataset_arg: tuple[bool, ...]    # which args are DeviceDatasets
    ds_local_len: tuple[int | None, ...]  # per-arg local shard length


@dataclasses.dataclass
class _CacheEntry:
    plan: _CallPlan
    exe: Callable                    # AOT-compiled executable
    op_leaves: list | None = None    # output Reduce ops (filled on 1st call)


class SynkFunction:
    def __init__(
        self,
        fn: Callable,
        in_specs: Sequence[Any],
        out_specs: Any = Reduce("mean"),
        *,
        ctx: ctx_mod.SynkContext | None = None,
        backend: str = "shard_map",
        name: str | None = None,
        donate: bool = False,
    ):
        self.fn = fn
        self.in_specs = tuple(canonicalize_in_spec(s) for s in in_specs)
        self.out_specs = jax.tree.map(
            canonicalize_out_spec, out_specs,
            is_leaf=lambda x: isinstance(x, (Reduce, str)) or x is None,
        )
        self.ctx = ctx or ctx_mod.current()
        if backend not in ("shard_map", "gspmd"):
            raise ValueError(backend)
        self.backend = backend
        self.name = name or getattr(fn, "__name__", "synk_fn")
        self.donate = donate
        # AOT executables per call signature (shared cache class with the
        # serve engine; its builds/cache_hits counters feed self.stats)
        self.aot = AotCache(self.name)
        # shardings are signature-independent; precompute per (spec, ndim)
        self._sharding_cache: dict[tuple, NamedSharding] = {}
        self._counters = {"calls": 0, "device_puts": 0, "device_put_skips": 0}

    @property
    def stats(self) -> dict:
        """Dispatch counters (calls/builds/cache_hits/device_puts/...)."""
        return {**self._counters, **self.aot.stats}

    # ------------------------------------------------------------------
    def __call__(self, *args, num_slices: int = 1, batch=None):
        if len(args) != len(self.in_specs):
            raise TypeError(
                f"{self.name} takes {len(self.in_specs)} inputs, got {len(args)}"
            )
        self._counters["calls"] += 1
        ctx = self.ctx
        n = ctx.n_data
        dataset_arg = tuple(is_dataset(a) for a in args)
        indexed = batch is not None

        idx_global = None
        orig_len = None
        if indexed:
            idx_global = np.asarray(batch)
            if idx_global.ndim != 1:
                raise ValueError("batch= must be a 1-D index array")
            orig_len = idx_global.shape[0]
            if orig_len == 0:
                raise ValueError("batch= may not be empty")
            if orig_len % n != 0:
                idx_global = _pad_indices(idx_global, n)

        routed = False
        ds_local_len: list[int | None] = [None] * len(args)
        if indexed and any(dataset_arg):
            k = idx_global.shape[0] // n
            owners = np.repeat(np.arange(n), k)
            lo, hi = int(idx_global.min()), int(idx_global.max())
            for i, (a, is_ds) in enumerate(zip(args, dataset_arg)):
                if is_ds:
                    if lo < 0 or hi >= len(a):
                        raise IndexError(
                            f"batch= ids must be global dataset rows in "
                            f"[0, {len(a)}); got range [{lo}, {hi}]"
                        )
                    ds_local_len[i] = a.local_length
                    if self.backend == "shard_map" and not routed:
                        routed = bool(
                            np.any(idx_global // a.local_length != owners)
                        )

        plan = _CallPlan(
            num_slices=num_slices, indexed=indexed, routed=routed,
            dataset_arg=dataset_arg, ds_local_len=tuple(ds_local_len),
        )
        key = self._signature(args, idx_global, plan)
        staged, extra = self._stage_args(args, idx_global, plan)
        entry = self.aot.get(key, lambda: self._build_entry(plan, staged, extra))
        out = entry.exe(*staged, *extra)
        return self._postprocess(entry, out, orig_len)

    # ------------------------------------------------------------------
    # Signature & staging
    # ------------------------------------------------------------------
    def _signature(self, args, idx_global, plan: _CallPlan):
        """Cache key from the RAW args — no staging required first."""
        sig = []
        for a, is_ds in zip(args, plan.dataset_arg):
            if is_ds:
                sig.append(("ds", a.array.shape, str(a.array.dtype)))
            elif is_host_data(a):
                sig.append(("host", a.shape, str(a.dtype)))
            else:
                leaves, treedef = jax.tree.flatten(a)
                sig.append((
                    "tree", treedef,
                    tuple((np.shape(l), str(getattr(l, "dtype", np.asarray(l).dtype)))
                          for l in leaves),
                ))
        idx_len = idx_global.shape[0] if plan.indexed else None
        return (
            tuple(sig), plan.num_slices, plan.indexed, plan.routed,
            plan.dataset_arg, idx_len,
        )

    def _target_sharding(self, spec, ndim: int) -> NamedSharding:
        key = (isinstance(spec, Scatter), ndim)
        sh = self._sharding_cache.get(key)
        if sh is None:
            ctx = self.ctx
            if isinstance(spec, Scatter):
                sh = ctx.sharding(ctx.data_spec(*([None] * (ndim - 1))))
            else:
                sh = ctx.sharding(P())
            self._sharding_cache[key] = sh
        return sh

    def _put(self, arr, spec) -> jax.Array:
        """Stage one leaf, skipping device_put when already resident with
        the target sharding."""
        ctx = self.ctx
        if not isinstance(arr, jax.Array):
            arr = jnp.asarray(arr)
        if isinstance(spec, Scatter) and arr.shape[0] % ctx.n_data != 0:
            raise ValueError(
                f"scattered input batch {arr.shape[0]} must divide the "
                f"data-parallel worker count {ctx.n_data}"
            )
        target = self._target_sharding(spec, arr.ndim)
        if getattr(arr, "sharding", None) == target:
            self._counters["device_put_skips"] += 1
            return arr
        self._counters["device_puts"] += 1
        return jax.device_put(arr, target)

    def _stage_args(self, args, idx_global, plan: _CallPlan):
        staged = []
        for a, spec, is_ds in zip(args, self.in_specs, plan.dataset_arg):
            if is_ds:
                if not isinstance(spec, Scatter):
                    raise ValueError("DeviceDataset inputs must use Scatter spec")
                staged.append(a.array)  # already sharded on device
            elif is_host_data(a):
                arr = (
                    a.excerpt(idx_global)
                    if (plan.indexed and isinstance(spec, Scatter)) else a.array
                )
                staged.append(self._put(arr, spec))
            else:
                def prep(leaf):
                    if plan.indexed and isinstance(spec, Scatter):
                        leaf = np.asarray(leaf)[idx_global]
                    return leaf
                staged.append(jax.tree.map(
                    lambda leaf: self._put(prep(leaf), spec), a))
        extra = ()
        if plan.indexed and any(plan.dataset_arg):
            # Device-resident indexing (paper §5.2): global row ids, either
            # scattered (aligned fast path) or replicated (routed path).
            idx_spec = Broadcast() if plan.routed else Scatter()
            extra = (self._put(idx_global.astype(np.int32), idx_spec),)
        return staged, extra

    def _postprocess(self, entry: _CacheEntry, out, orig_len):
        """Slice padded ``concat`` outputs back to the request length."""
        if orig_len is None:
            return out
        leaves, tree = jax.tree.flatten(out)
        if entry.op_leaves is None:
            entry.op_leaves = _flatten_ops(self.out_specs, tree)
        if not any(op.op == "concat" for op in entry.op_leaves):
            return out
        cut = [
            (leaf[:orig_len] if op.op == "concat" and leaf.shape
             and leaf.shape[0] >= orig_len else leaf)
            for leaf, op in zip(leaves, entry.op_leaves)
        ]
        return jax.tree.unflatten(tree, cut)

    # ------------------------------------------------------------------
    # Build: trace + AOT-compile one executable per signature
    # ------------------------------------------------------------------
    def _build_entry(self, plan: _CallPlan, staged, extra) -> _CacheEntry:
        if self.backend == "shard_map":
            jitted = self._build_shard_map(plan, staged, extra)
        else:
            jitted = self._build_gspmd(plan, staged, extra)
        exe = jitted.lower(*staged, *extra).compile()
        return _CacheEntry(plan=plan, exe=exe)

    def _donate_argnums(self, plan: _CallPlan) -> tuple[int, ...]:
        """Donate scattered array inputs; never DeviceDatasets (persistent)
        or broadcast state.  Host inputs are freshly staged so donation is
        free; device-resident inputs passed by the caller are consumed
        (``jax.jit`` donate_argnums semantics — see class docstring)."""
        if not self.donate:
            return ()
        return tuple(
            i for i, (spec, is_ds) in enumerate(zip(self.in_specs, plan.dataset_arg))
            if isinstance(spec, Scatter) and not is_ds
        )

    def _sliceable_mask(self, plan: _CallPlan) -> list[bool]:
        # A worker slices the args it scattered (incl. gathered dataset rows).
        return [isinstance(s, Scatter) for s in self.in_specs]

    def _worker_index(self):
        """Combined index along the (possibly nested) data axes."""
        ctx = self.ctx
        idx = jax.lax.axis_index(ctx.data_axes[0])
        for a in ctx.data_axes[1:]:
            idx = idx * ctx.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def _take_dataset_rows(self, plan: _CallPlan, dev_args: list, local_idx):
        """Per-worker gather of dataset rows for global ``batch=`` indices."""
        n = self.ctx.n_data
        w = self._worker_index()
        for i, is_ds in enumerate(plan.dataset_arg):
            if not is_ds:
                continue
            L = plan.ds_local_len[i]
            arr = dev_args[i]
            if not plan.routed:
                # aligned: this worker's index chunk lies in its own shard
                rel = local_idx - w * L
                dev_args[i] = jnp.take(arr, rel, axis=0)
            else:
                # routed: every worker sees all B indices; each contributes
                # the rows it owns, a psum assembles the full gathered batch,
                # and the worker keeps its chunk.
                rel = local_idx - w * L
                own = (rel >= 0) & (rel < L)
                rows = jnp.take(arr, jnp.clip(rel, 0, L - 1), axis=0)
                mask = own.reshape(own.shape + (1,) * (rows.ndim - 1))
                rows = jnp.where(mask, rows, jnp.zeros((), rows.dtype))
                rows = jax.lax.psum(rows, self.ctx.data_axes)
                k = local_idx.shape[0] // n
                dev_args[i] = jax.lax.dynamic_slice_in_dim(rows, w * k, k, axis=0)
        return dev_args

    def _build_shard_map(self, plan: _CallPlan, staged, extra) -> Callable:
        ctx = self.ctx
        daxes = ctx.data_axes
        mask = self._sliceable_mask(plan)

        def device_fn(*dev_args):
            dev_args = list(dev_args)
            if plan.indexed and any(plan.dataset_arg):
                local_idx = dev_args[-1]
                dev_args = self._take_dataset_rows(plan, dev_args[:-1], local_idx)
            if plan.num_slices > 1:
                out = sliced_call(
                    self.fn, dev_args, mask, self.out_specs, plan.num_slices,
                    vary_axes=daxes,
                )
            else:
                out = self.fn(*dev_args)
            return self._apply_reduces(out, daxes)

        in_specs = []
        for a, spec in zip(staged, self.in_specs):
            if isinstance(spec, Scatter):
                in_specs.append(jax.tree.map(
                    lambda l: P(daxes, *([None] * (l.ndim - 1))), a))
            else:
                in_specs.append(jax.tree.map(lambda l: P(), a))
        if plan.indexed and any(plan.dataset_arg):
            in_specs.append(P() if plan.routed else P(daxes))

        out_shape = jax.eval_shape(
            lambda *xs: self.fn(*self._probe_args(xs, plan)), *staged, *extra
        )
        out_tree = jax.tree.structure(out_shape)
        op_leaves = _flatten_ops(self.out_specs, out_tree)
        out_pspecs = jax.tree.unflatten(
            out_tree,
            [self._out_pspec(op, daxes) for op in op_leaves],
        )
        # check_vma=False: keep per-worker results LOCAL until the explicit
        # reduce below (paper semantics).  With VMA tracking on, jax.grad of
        # a replicated input inside shard_map auto-inserts a psum (the
        # pbroadcast transpose), silently pre-reducing user gradients.
        mapped = compat.shard_map(
            device_fn, mesh=ctx.mesh, in_specs=tuple(in_specs),
            out_specs=out_pspecs, check_vma=False,
        )
        return jax.jit(mapped, donate_argnums=self._donate_argnums(plan))

    def _probe_args(self, xs, plan: _CallPlan):
        """Build abstract per-worker args for output-structure discovery."""
        ctx = self.ctx
        xs = list(xs)
        if plan.indexed and any(plan.dataset_arg):
            idx = xs[-1]
            xs = xs[:-1]
        out = []
        for a, spec, is_ds in zip(xs, self.in_specs, plan.dataset_arg):
            if isinstance(spec, Scatter):
                def shrink(l):
                    b = l.shape[0] // ctx.n_data
                    if is_ds and plan.indexed:
                        b = idx.shape[0] // ctx.n_data
                    return jnp.zeros((b,) + l.shape[1:], l.dtype)
                out.append(jax.tree.map(shrink, a))
            else:
                out.append(a)
        return out

    @staticmethod
    def _out_pspec(op: Reduce, daxes) -> P:
        if op.op in ("mean", "sum", "max", "min", "last"):
            return P()
        if op.op == "concat":
            return P(daxes)
        return P(daxes)  # None: stacked per-worker results, leading axis

    def _apply_reduces(self, out, daxes):
        leaves, tree = jax.tree.flatten(out)
        op_leaves = _flatten_ops(self.out_specs, tree)
        red = []
        for val, op in zip(leaves, op_leaves):
            if op.op == "mean":
                red.append(jax.lax.pmean(val, daxes))
            elif op.op == "sum":
                red.append(jax.lax.psum(val, daxes))
            elif op.op == "max":
                red.append(jax.lax.pmax(val, daxes))
            elif op.op == "min":
                red.append(jax.lax.pmin(val, daxes))
            elif op.op == "last":
                # identical-by-construction state: return worker 0's copy
                red.append(jax.lax.all_gather(val, daxes, axis=0, tiled=False)[0])
            elif op.op == "concat":
                red.append(val)  # out_spec P(daxes) concatenates shards
            else:  # None: per-worker results stacked on a new leading axis
                red.append(val[None])
        return jax.tree.unflatten(tree, red)

    # ------------------------------------------------------------------
    def _build_gspmd(self, plan: _CallPlan, staged, extra) -> Callable:
        """Beyond-paper backend: fn is the global program; XLA partitions it."""
        ctx = self.ctx
        mask = self._sliceable_mask(plan)

        def global_fn(*g_args):
            g_args = list(g_args)
            if plan.indexed and any(plan.dataset_arg):
                idx = g_args[-1]
                g_args = g_args[:-1]
                for i, is_ds in enumerate(plan.dataset_arg):
                    if is_ds:
                        g_args[i] = jnp.take(g_args[i], idx, axis=0)
            if plan.num_slices > 1:
                return sliced_call(self.fn, g_args, mask, self.out_specs, plan.num_slices)
            return self.fn(*g_args)

        in_sh = []
        for a, spec in zip(staged, self.in_specs):
            if isinstance(spec, Scatter):
                in_sh.append(ctx.sharding(ctx.data_spec(*([None] * (a.ndim - 1)))))
            else:
                in_sh.append(ctx.sharding(P()))
        if plan.indexed and any(plan.dataset_arg):
            in_sh.append(ctx.sharding(ctx.data_spec()))
        return jax.jit(
            global_fn, in_shardings=tuple(in_sh),
            donate_argnums=self._donate_argnums(plan),
        )


def function(
    fn: Callable,
    inputs: Sequence[Any],
    outputs: Any = "mean",
    *,
    ctx: ctx_mod.SynkContext | None = None,
    backend: str = "shard_map",
    name: str | None = None,
    donate: bool = False,
) -> SynkFunction:
    """Paper's ``synk.function`` (replacing ``theano.function``)."""
    return SynkFunction(
        fn, inputs, outputs, ctx=ctx, backend=backend, name=name, donate=donate,
    )


def _pad_indices(idx: np.ndarray, n: int) -> np.ndarray:
    """Pad an index list so it scatters evenly (paper: 'as equal as
    possible' — we repeat trailing indices, cycling when the pad exceeds
    the list; reductions stay approximately correct and ``concat`` outputs
    are sliced back to the original request length)."""
    pad = (-len(idx)) % n
    if not pad:
        return idx
    if len(idx) == 0:
        raise ValueError("batch= may not be empty")
    tail = np.resize(idx[::-1], pad)[::-1]
    return np.concatenate([idx, tail])
