"""``synk.function`` — data-parallel execution of a serial function.

The user writes a serial ``fn`` over its batch of inputs; calling the
Synkhronos function induces the paper's §3.2 sequence:

  1) data inputs are scattered equally across workers,
  2) each worker calls the same function on its assigned data,
  3) results are reduced or gathered back and returned.

Two backends:

* ``shard_map`` (default, paper-faithful): an explicit per-worker program.
  Each device runs ``fn`` on its shard; outputs are combined with
  ``lax.pmean/psum/pmax/pmin/all_gather`` according to each output's
  :class:`Reduce` spec.  Updates to state are local per worker unless the
  user reduces them — exactly the paper's semantics.

* ``gspmd``: ``jax.jit`` with batch-sharded ``in_shardings``.  Here ``fn``
  is the *global* program and XLA inserts/overlaps collectives.  This is
  the beyond-paper optimized path used by the large-scale trainer.

Both support the paper's §5 extensions: ``num_slices=`` (automated input
slicing with aggregation) and ``batch=`` (input indexing, host- or
device-resident).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import context as ctx_mod
from .data import DeviceDataset, SynkData, is_dataset, is_host_data
from .slicing import _flatten_ops, sliced_call
from .specs import Broadcast, Reduce, Scatter, canonicalize_in_spec, canonicalize_out_spec


@dataclasses.dataclass
class _CallPlan:
    """Static description of one call signature (cache key companion)."""

    num_slices: int
    indexed: bool            # batch= indices present
    dataset_arg: tuple[bool, ...]   # which args are DeviceDatasets


class SynkFunction:
    def __init__(
        self,
        fn: Callable,
        in_specs: Sequence[Any],
        out_specs: Any = Reduce("mean"),
        *,
        ctx: ctx_mod.SynkContext | None = None,
        backend: str = "shard_map",
        name: str | None = None,
    ):
        self.fn = fn
        self.in_specs = tuple(canonicalize_in_spec(s) for s in in_specs)
        self.out_specs = jax.tree.map(
            canonicalize_out_spec, out_specs,
            is_leaf=lambda x: isinstance(x, (Reduce, str)) or x is None,
        )
        self.ctx = ctx or ctx_mod.current()
        if backend not in ("shard_map", "gspmd"):
            raise ValueError(backend)
        self.backend = backend
        self.name = name or getattr(fn, "__name__", "synk_fn")
        self._cache: dict[Any, Callable] = {}

    # ------------------------------------------------------------------
    def __call__(self, *args, num_slices: int = 1, batch=None):
        if len(args) != len(self.in_specs):
            raise TypeError(
                f"{self.name} takes {len(self.in_specs)} inputs, got {len(args)}"
            )
        ctx = self.ctx
        dataset_arg = tuple(is_dataset(a) for a in args)
        indexed = batch is not None

        staged = []
        idx_global = None
        if indexed:
            idx_global = np.asarray(batch)
            if idx_global.ndim != 1:
                raise ValueError("batch= must be a 1-D index array")
            n = ctx.n_data
            if idx_global.shape[0] % n != 0:
                idx_global = _pad_indices(idx_global, n)
        for a, spec, is_ds in zip(args, self.in_specs, dataset_arg):
            if is_ds:
                if not isinstance(spec, Scatter):
                    raise ValueError("DeviceDataset inputs must use Scatter spec")
                staged.append(a.array)  # already sharded on device
            elif is_host_data(a):
                arr = a.excerpt(idx_global) if (indexed and isinstance(spec, Scatter)) else a.array
                staged.append(self._stage(arr, spec))
            else:
                def prep(leaf):
                    if indexed and isinstance(spec, Scatter):
                        leaf = np.asarray(leaf)[idx_global]
                    return leaf
                staged.append(jax.tree.map(
                    lambda leaf: self._stage(prep(leaf), spec), a))

        plan = _CallPlan(num_slices=num_slices, indexed=indexed, dataset_arg=dataset_arg)
        extra = ()
        if indexed and any(dataset_arg):
            # Device-resident indexing (paper §5.2): indices are scattered and
            # applied to each worker's local shard.
            local_idx = idx_global
            extra = (self._stage(local_idx.astype(np.int32), Scatter()),)
        key = self._key(staged, plan)
        if key not in self._cache:
            self._cache[key] = self._build(plan, staged, extra)
        return self._cache[key](*staged, *extra)

    # ------------------------------------------------------------------
    def _stage(self, arr, spec) -> jax.Array:
        ctx = self.ctx
        arr = jnp.asarray(arr) if not isinstance(arr, jax.Array) else arr
        if isinstance(spec, Scatter):
            if arr.shape[0] % ctx.n_data != 0:
                raise ValueError(
                    f"scattered input batch {arr.shape[0]} must divide the "
                    f"data-parallel worker count {ctx.n_data}"
                )
            sh = ctx.sharding(ctx.data_spec(*([None] * (arr.ndim - 1))))
        else:
            sh = ctx.sharding(P())
        return jax.device_put(arr, sh)

    def _key(self, staged, plan: _CallPlan):
        shapes = tuple(
            tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(a))
            + (jax.tree.structure(a),)
            for a in staged
        )
        return (shapes, plan.num_slices, plan.indexed, plan.dataset_arg)

    # ------------------------------------------------------------------
    def _build(self, plan: _CallPlan, staged, extra) -> Callable:
        if self.backend == "shard_map":
            return self._build_shard_map(plan, staged, extra)
        return self._build_gspmd(plan, staged, extra)

    def _sliceable_mask(self, plan: _CallPlan) -> list[bool]:
        # A worker slices the args it scattered (incl. gathered dataset rows).
        return [isinstance(s, Scatter) for s in self.in_specs]

    def _build_shard_map(self, plan: _CallPlan, staged, extra) -> Callable:
        ctx = self.ctx
        daxes = ctx.data_axes
        mask = self._sliceable_mask(plan)

        def device_fn(*dev_args):
            dev_args = list(dev_args)
            if plan.indexed and any(plan.dataset_arg):
                local_idx = dev_args[-1]
                dev_args = dev_args[:-1]
                for i, is_ds in enumerate(plan.dataset_arg):
                    if is_ds:
                        dev_args[i] = jnp.take(dev_args[i], local_idx, axis=0)
            if plan.num_slices > 1:
                out = sliced_call(
                    self.fn, dev_args, mask, self.out_specs, plan.num_slices,
                    vary_axes=daxes,
                )
            else:
                out = self.fn(*dev_args)
            return self._apply_reduces(out, daxes)

        in_specs = []
        for a, spec in zip(staged, self.in_specs):
            if isinstance(spec, Scatter):
                in_specs.append(jax.tree.map(
                    lambda l: P(daxes, *([None] * (l.ndim - 1))), a))
            else:
                in_specs.append(jax.tree.map(lambda l: P(), a))
        if plan.indexed and any(plan.dataset_arg):
            in_specs.append(P(daxes))

        out_shape = jax.eval_shape(
            lambda *xs: self.fn(*self._probe_args(xs, plan)), *staged, *extra
        )
        out_tree = jax.tree.structure(out_shape)
        op_leaves = _flatten_ops(self.out_specs, out_tree)
        out_pspecs = jax.tree.unflatten(
            out_tree,
            [self._out_pspec(op, daxes) for op in op_leaves],
        )
        # check_vma=False: keep per-worker results LOCAL until the explicit
        # reduce below (paper semantics).  With VMA tracking on, jax.grad of
        # a replicated input inside shard_map auto-inserts a psum (the
        # pbroadcast transpose), silently pre-reducing user gradients.
        mapped = jax.shard_map(
            device_fn, mesh=ctx.mesh, in_specs=tuple(in_specs),
            out_specs=out_pspecs, check_vma=False,
        )
        return jax.jit(mapped)

    def _probe_args(self, xs, plan: _CallPlan):
        """Build abstract per-worker args for output-structure discovery."""
        ctx = self.ctx
        xs = list(xs)
        if plan.indexed and any(plan.dataset_arg):
            idx = xs[-1]
            xs = xs[:-1]
        out = []
        for a, spec, is_ds in zip(xs, self.in_specs, plan.dataset_arg):
            if isinstance(spec, Scatter):
                def shrink(l):
                    b = l.shape[0] // ctx.n_data
                    if is_ds and plan.indexed:
                        b = idx.shape[0] // ctx.n_data
                    return jnp.zeros((b,) + l.shape[1:], l.dtype)
                out.append(jax.tree.map(shrink, a))
            else:
                out.append(a)
        return out

    @staticmethod
    def _out_pspec(op: Reduce, daxes) -> P:
        if op.op in ("mean", "sum", "max", "min", "last"):
            return P()
        if op.op == "concat":
            return P(daxes)
        return P(daxes)  # None: stacked per-worker results, leading axis

    def _apply_reduces(self, out, daxes):
        leaves, tree = jax.tree.flatten(out)
        op_leaves = _flatten_ops(self.out_specs, tree)
        red = []
        for val, op in zip(leaves, op_leaves):
            if op.op == "mean":
                red.append(jax.lax.pmean(val, daxes))
            elif op.op == "sum":
                red.append(jax.lax.psum(val, daxes))
            elif op.op == "max":
                red.append(jax.lax.pmax(val, daxes))
            elif op.op == "min":
                red.append(jax.lax.pmin(val, daxes))
            elif op.op == "last":
                # identical-by-construction state: return worker 0's copy
                red.append(jax.lax.all_gather(val, daxes, axis=0, tiled=False)[0])
            elif op.op == "concat":
                red.append(val)  # out_spec P(daxes) concatenates shards
            else:  # None: per-worker results stacked on a new leading axis
                red.append(val[None])
        return jax.tree.unflatten(tree, red)

    # ------------------------------------------------------------------
    def _build_gspmd(self, plan: _CallPlan, staged, extra) -> Callable:
        """Beyond-paper backend: fn is the global program; XLA partitions it."""
        ctx = self.ctx
        mask = self._sliceable_mask(plan)

        def global_fn(*g_args):
            g_args = list(g_args)
            if plan.indexed and any(plan.dataset_arg):
                idx = g_args[-1]
                g_args = g_args[:-1]
                for i, is_ds in enumerate(plan.dataset_arg):
                    if is_ds:
                        g_args[i] = jnp.take(g_args[i], idx, axis=0)
            if plan.num_slices > 1:
                return sliced_call(self.fn, g_args, mask, self.out_specs, plan.num_slices)
            return self.fn(*g_args)

        in_sh = []
        for a, spec in zip(staged, self.in_specs):
            if isinstance(spec, Scatter):
                in_sh.append(ctx.sharding(ctx.data_spec(*([None] * (a.ndim - 1)))))
            else:
                in_sh.append(ctx.sharding(P()))
        if plan.indexed and any(plan.dataset_arg):
            in_sh.append(ctx.sharding(ctx.data_spec()))
        return jax.jit(global_fn, in_shardings=tuple(in_sh))


def function(
    fn: Callable,
    inputs: Sequence[Any],
    outputs: Any = "mean",
    *,
    ctx: ctx_mod.SynkContext | None = None,
    backend: str = "shard_map",
    name: str | None = None,
) -> SynkFunction:
    """Paper's ``synk.function`` (replacing ``theano.function``)."""
    return SynkFunction(fn, inputs, outputs, ctx=ctx, backend=backend, name=name)


def _pad_indices(idx: np.ndarray, n: int) -> np.ndarray:
    """Pad an index list so it scatters evenly (paper: 'as equal as
    possible' — we repeat trailing indices; reductions stay approximately
    correct and concat callers should slice to the original length)."""
    pad = (-len(idx)) % n
    return np.concatenate([idx, idx[-pad:]]) if pad else idx
