"""Automated input slicing with aggregation (paper §5.1).

When a function call is too large for one device invocation, the worker
computes its result by scanning over ``num_slices`` subsets of its assigned
data and aggregating in place on the device.  Aggregation follows each
output's reduce spec; results are reduced across workers only once, after
the scan (paper: "Slice results are aggregated in-place on the GPU. Worker
results are reduced once back to the master process").

All slices see the *original* values of broadcast inputs (paper: "all
slices are computed using the original values, with updates accumulated and
applied only once at the end") — i.e. this is gradient accumulation when
the sliced function computes gradients.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from .specs import Reduce


def _split_leading(x, k: int):
    b = x.shape[0]
    if b % k != 0:
        raise ValueError(
            f"num_slices={k} must divide the per-worker batch {b} "
            f"(paper pads inputs 'as equal as possible'; pass a divisible batch)"
        )
    return x.reshape((k, b // k) + x.shape[1:])


def _acc_init(shape_dtype: jax.ShapeDtypeStruct, op: str | None):
    if op == "max":
        return jnp.full(shape_dtype.shape, -jnp.inf, shape_dtype.dtype)
    if op == "min":
        return jnp.full(shape_dtype.shape, jnp.inf, shape_dtype.dtype)
    # mean / sum accumulate in the output dtype; float accumulators promoted
    # to f32 to avoid bf16 drift across many slices.
    dt = shape_dtype.dtype
    if op in ("mean", "sum") and dt in (jnp.bfloat16, jnp.float16):
        dt = jnp.float32
    return jnp.zeros(shape_dtype.shape, dt)


def _acc_update(acc, val, op: str | None, k: int):
    if op == "mean":
        return acc + val.astype(acc.dtype) / k
    if op == "sum":
        return acc + val.astype(acc.dtype)
    if op == "max":
        return jnp.maximum(acc, val)
    if op == "min":
        return jnp.minimum(acc, val)
    raise AssertionError(op)


def sliced_call(
    fn: Callable,
    args: Sequence[Any],
    sliced_mask: Sequence[bool],
    out_ops: Any,               # pytree of Reduce matching fn's output
    num_slices: int,
    vary_axes: tuple[str, ...] = (),
):
    """Run ``fn(*args)`` as a ``lax.scan`` over ``num_slices`` slices.

    ``sliced_mask[i]`` — whether args[i] is split along its leading axis.
    Outputs with op mean/sum/max/min are accumulated; ``concat``/``None``
    outputs are stacked and re-flattened; ``last`` keeps the final slice.
    """
    k = num_slices
    split_args = [
        jax.tree.map(lambda x: _split_leading(x, k), a) if m else a
        for a, m in zip(args, sliced_mask)
    ]

    # Discover output structure abstractly.
    def first_slice(a, m):
        return jax.tree.map(lambda x: x[0], a) if m else a

    probe_args = [first_slice(a, m) for a, m in zip(split_args, sliced_mask)]
    out_shape = jax.eval_shape(fn, *probe_args)
    out_leaves, out_tree = jax.tree.flatten(out_shape)
    op_leaves = _flatten_ops(out_ops, out_tree)

    def _vary(x):
        # Inside shard_map, carries must match the per-slice outputs' varying
        # manual axes (data-derived values vary over the data axes).
        return compat.pvary(x, vary_axes) if vary_axes else x

    acc_init = [
        _vary(_acc_init(sd, op.op)) if op.op in ("mean", "sum", "max", "min") else None
        for sd, op in zip(out_leaves, op_leaves)
    ]
    last_init = [
        _vary(jnp.zeros(sd.shape, sd.dtype)) if op.op == "last" else None
        for sd, op in zip(out_leaves, op_leaves)
    ]

    def body(carry, xs):
        accs, lasts = carry
        sl_args = []
        xs_iter = iter(xs)
        for a, m in zip(args, sliced_mask):
            sl_args.append(next(xs_iter) if m else a)
        out = fn(*sl_args)
        flat = jax.tree.flatten(out)[0]
        new_accs, new_lasts, ys = [], [], []
        for i, (val, op) in enumerate(zip(flat, op_leaves)):
            if op.op in ("mean", "sum", "max", "min"):
                new_accs.append(_acc_update(accs[i], val, op.op, k))
                new_lasts.append(lasts[i])
                ys.append(None)
            elif op.op == "last":
                new_accs.append(accs[i])
                new_lasts.append(val)
                ys.append(None)
            else:  # concat / None: stack slices
                new_accs.append(accs[i])
                new_lasts.append(lasts[i])
                ys.append(val)
        return (new_accs, new_lasts), ys

    xs = [a for a, m in zip(split_args, sliced_mask) if m]
    (accs, lasts), ys = jax.lax.scan(body, (acc_init, last_init), xs, length=k)

    out_flat = []
    for i, (sd, op) in enumerate(zip(out_leaves, op_leaves)):
        if op.op in ("mean", "sum", "max", "min"):
            out_flat.append(accs[i].astype(sd.dtype))
        elif op.op == "last":
            out_flat.append(lasts[i])
        else:  # (k, b/k, ...) -> (b, ...)
            y = ys[i]
            out_flat.append(y.reshape((y.shape[0] * y.shape[1],) + y.shape[2:]))
    return jax.tree.unflatten(out_tree, out_flat)


def _flatten_ops(out_ops, out_tree) -> list[Reduce]:
    """Broadcast a Reduce spec (single or pytree-PREFIX) over the output
    tree: a Reduce at an interior position applies to every leaf below it
    (so ``(Reduce("mean"), Reduce(None))`` matches ``(loss, params_dict)``)."""
    if isinstance(out_ops, Reduce):
        return [out_ops] * out_tree.num_leaves
    from jax.api_util import flatten_axes
    flat = flatten_axes("synk.function outputs", out_tree, out_ops)
    return [op if isinstance(op, Reduce) else Reduce(op) for op in flat]
