"""Input/output specifications for Synkhronos functions.

Mirrors the paper's interface: inputs are either *scattered* (split along
the leading axis across data-parallel workers — paper §4.1 "the lowest
tensor dimension is taken to represent independent data points") or
*broadcast* (used as-is on every worker).  Outputs carry a reduce/gather
operation (paper §3.1 "the ability to specify a reduce/gather operation to
use for each output").
"""
from __future__ import annotations

import dataclasses
from typing import Any

REDUCE_OPS = ("mean", "sum", "max", "min", "concat", "last", None)


@dataclasses.dataclass(frozen=True)
class Scatter:
    """Split this input along ``axis`` across the data-parallel workers."""

    axis: int = 0

    def __post_init__(self):
        if self.axis != 0:
            raise NotImplementedError(
                "Synkhronos scatters along the leading axis (paper §4.1); "
                "move the batch dimension to axis 0."
            )


@dataclasses.dataclass(frozen=True)
class Broadcast:
    """Replicate this input on every worker (paper: 'inputs designated for
    broadcast are simply used as is')."""


@dataclasses.dataclass(frozen=True)
class Reduce:
    """Reduce this output across workers with ``op``.

    ``mean``/``sum``/``max``/``min`` — elementwise tree reduction
    (paper: NCCL reduce back to master; here: ``lax.p*`` collectives).
    ``concat`` — gather along the leading axis (paper: gather).
    ``last``  — slicing aggregation only: keep the final slice's value
                (e.g. carried state); across workers behaves like concat.
    ``None``  — leave per-worker values stacked on a leading axis.
    """

    op: str | None = "mean"

    def __post_init__(self):
        if self.op not in REDUCE_OPS:
            raise ValueError(f"unknown reduce op {self.op!r}; choose from {REDUCE_OPS}")


def canonicalize_in_spec(spec: Any) -> Scatter | Broadcast:
    if isinstance(spec, (Scatter, Broadcast)):
        return spec
    if spec == "scatter":
        return Scatter()
    if spec == "broadcast" or spec == "bcast":
        return Broadcast()
    raise ValueError(f"bad input spec {spec!r}")


def canonicalize_out_spec(spec: Any) -> Reduce:
    if isinstance(spec, Reduce):
        return spec
    if spec in REDUCE_OPS:
        return Reduce(spec)
    if spec == "avg":  # paper spells it 'avg'
        return Reduce("mean")
    raise ValueError(f"bad output spec {spec!r}")
