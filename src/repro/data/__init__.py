from .pipeline import (
    DataConfig, SyntheticEmbeds, SyntheticTokens, host_corpus, make_batch_fn,
)

__all__ = [
    "DataConfig", "SyntheticEmbeds", "SyntheticTokens", "host_corpus",
    "make_batch_fn",
]
