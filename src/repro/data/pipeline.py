"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step): resume-after-failure replays
the exact same stream with no stored iterator state — the data-side half of
fault tolerance.  The host staging buffer is a Synkhronos data object
(paper §4.1), and ``device_dataset`` pre-scatters a corpus across HBM
(paper §4.2) for the input-indexing fast path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.data import SynkData


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    """Deterministic LM token stream: batch(step) -> (B, S+1) int32."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng([c.seed, step])
        # Markov-ish stream so a model can actually reduce loss on it:
        # token_{t+1} = (a * token_t + b + noise) % vocab
        B, S = c.global_batch, c.seq_len
        a = 31
        start = rng.integers(0, c.vocab, size=(B, 1))
        noise = (rng.random(size=(B, S)) < 0.1).astype(np.int64)
        toks = [start[:, 0]]
        for t in range(S):
            toks.append((a * toks[-1] + 7 + noise[:, t]) % c.vocab)
        return np.stack(toks, axis=1).astype(np.int32)


class SyntheticEmbeds:
    """Deterministic float frontend stubs (VLM patches / audio frames)."""

    def __init__(self, shape: tuple[int, ...], seed: int = 0):
        self.shape = shape
        self.seed = seed

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng([self.seed, 1_000_003, step])
        return rng.standard_normal(self.shape, dtype=np.float32)


def make_batch_fn(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
    """Returns batch(step) -> dict matching registry.train_inputs."""
    s_text = shape.seq_len - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    toks = SyntheticTokens(
        DataConfig(cfg.vocab, s_text, shape.global_batch, seed)
    )
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = SyntheticEmbeds(
            (shape.global_batch, cfg.frontend_tokens, cfg.frontend_dim), seed
        )
    if cfg.family == "audio":
        extras["frames"] = SyntheticEmbeds(
            (shape.global_batch, cfg.enc_seq, cfg.d_model), seed
        )

    def fn(step: int) -> dict:
        b = {"tokens": toks.batch(step)}
        for k, gen in extras.items():
            b[k] = gen.batch(step)
        return b

    return fn


def host_corpus(cfg: ArchConfig, n_examples: int, seq_len: int, seed: int = 0) -> SynkData:
    """A shared-memory-style corpus for the input-indexing path."""
    stream = SyntheticTokens(DataConfig(cfg.vocab, seq_len, n_examples, seed))
    return SynkData(stream.batch(0))
