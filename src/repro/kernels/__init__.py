"""Pallas TPU kernels for the compute hot spots.

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jitted wrapper), ``ref.py`` (pure-jnp oracle).  Validated in
interpret mode on CPU; TPU is the compilation target.
"""
from .flash_attention.ops import flash_attention
from .flat_adam.ops import flat_adam_op
from .paged_attention.ops import paged_attention
from .rmsnorm.ops import rmsnorm_add_op, rmsnorm_op
from .ssd.ops import ssd_model_layout, ssd_op

__all__ = [
    "flash_attention", "flat_adam_op", "paged_attention",
    "rmsnorm_add_op", "rmsnorm_op", "ssd_model_layout", "ssd_op",
]
