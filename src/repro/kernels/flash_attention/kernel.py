"""Flash attention forward — Pallas TPU kernel.

Blocking: grid = (batch, q_heads, Sq / bq).  Each program owns one query
block (bq, d) in VMEM plus the full K/V stream for its KV head (GQA: the
index_map folds q-head -> kv-head).  The inner ``fori_loop`` walks KV
blocks with **dynamic bounds**: causal masking skips blocks above the
diagonal, sliding windows skip blocks below the band — the FLOP savings
the XLA fallback (models/attention.chunked_attention) can only mask.

Online-softmax state (m, l, acc) lives in fp32 VMEM scratch; supports
logit softcap (gemma2) and GQA.  MXU alignment: bq and d should be
multiples of 128 on real TPU (v5e); correctness holds for any size in
interpret mode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, *,
    bq: int, bk: int, sk: int,
    causal: bool, window: int, softcap: float, scale: float,
):
    qi = pl.program_id(2)
    # unit slices (not bare ints): bare-int ref indices don't normalize on
    # older Pallas interpret mode
    q = q_ref[pl.ds(0, 1), pl.ds(0, 1)][0, 0].astype(jnp.float32) * scale  # (bq, d)
    d = q.shape[-1]

    q_start = qi * bq
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    nk = sk // bk
    if causal:
        # highest kv block that the last row of this q block can see
        hi = jnp.minimum((q_start + bq - 1) // bk + 1, nk)
    else:
        hi = nk
    if causal and window:
        lo = jnp.maximum((q_start - window + 1) // bk, 0)
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(
            k_ref, (pl.ds(0, 1), pl.ds(0, 1), pl.ds(j * bk, bk), slice(None))
        )[0, 0].astype(jnp.float32)
        v = pl.load(
            v_ref, (pl.ds(0, 1), pl.ds(0, 1), pl.ds(j * bk, bk), slice(None))
        )[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                 # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[pl.ds(0, 1), pl.ds(0, 1)] = out.astype(o_ref.dtype)[None, None]


def flash_attention_fwd(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """q: (B, H, Sq, D); k/v: (B, Hk, Sk, D).  Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    rep = H // Hk
    bq = block_q
    while Sq % bq:
        bq //= 2
    bk = block_k
    while Sk % bk:
        bk //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _fwd_kernel,
        bq=bq, bk=bk, sk=Sk,
        causal=causal, window=window, softcap=softcap,
        scale=D ** -0.5,
    )
    grid = (B, H, Sq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
