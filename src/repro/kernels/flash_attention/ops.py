"""Jitted public wrapper for the flash attention kernel.

Accepts the model layout (B, S, H, D) used across models/, transposes to
the kernel layout, and dispatches to the Pallas kernel.

Differentiable: a ``custom_vjp`` runs the fused kernel on the forward
pass and recomputes attention through the memory-bounded XLA path
(``models.attention.chunked_attention``) for the backward — the standard
recompute-backward pairing for a forward-only kernel (saves only q/k/v,
never the score matrix).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import attention_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_fwd(
        qt, kt, vt,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    out = _flash(q, k, v, causal, window, softcap, block_q, block_k, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, softcap, block_q, block_k, interpret,
               residuals, g):
    from repro.models.attention import chunked_attention
    q, k, v = residuals

    def ref_fn(q, k, v):
        return chunked_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_chunk=block_q, kv_chunk=block_k,
        )

    _, vjp = jax.vjp(ref_fn, q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                                   "block_k", "interpret"))
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """q: (B, Sq, H, D); k/v: (B, Sk, Hk, D) — model layout."""
    return _flash(q, k, v, causal, window, softcap, block_q, block_k, interpret)
