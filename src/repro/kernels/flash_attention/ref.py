"""Pure-jnp oracle for the flash attention kernel (naive O(S^2) memory)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B, H, Sq, D); k/v: (B, Hk, Sk, D) with H % Hk == 0."""
    B, H, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    rep = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Hk, rep, Sq, D)
    s = jnp.einsum("bhrqd,bhkd->bhrqk", qf, k.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos_q = jnp.arange(Sq)[:, None]
    pos_k = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= pos_k <= pos_q
    if window:
        ok &= pos_k > pos_q - window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bhkd->bhrqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
