"""Fused flat-buffer Adam — Pallas TPU kernel.

The paper (§3.3) flattens all gradients into one array so the all-reduce
is a single collective; this kernel is the natural conclusion: the
optimizer update is ONE fused elementwise pass over the flat fp32
buffers (p, g, m, v -> p', m', v'), instead of one kernel launch and
3x read + 3x write per parameter tensor.  Grid over 1-D tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(step_ref, p_ref, g_ref, m_ref, v_ref,
                 p_out, m_out, v_out, *,
                 lr: float, beta1: float, beta2: float, eps: float,
                 weight_decay: float):
    t = step_ref[0].astype(jnp.float32)
    p = p_ref[...]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1 ** t)
    vhat = v / (1.0 - beta2 ** t)
    upd = lr * mhat / (jnp.sqrt(vhat) + eps)
    if weight_decay:
        upd = upd + lr * weight_decay * p
    p_out[...] = p - upd
    m_out[...] = m
    v_out[...] = v


def flat_adam(p, g, m, v, step, *,
              lr: float, beta1: float = 0.9, beta2: float = 0.95,
              eps: float = 1e-8, weight_decay: float = 0.0,
              block: int = 65536, interpret: bool | None = None):
    """All buffers: (n,) fp32, n % block == 0 (the FlatLayout pads).

    step: (1,) int32 — 1-based step count.  Returns (p', m', v').
    """
    n = p.shape[0]
    while n % block:
        block //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(
        _adam_kernel, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
        weight_decay=weight_decay,
    )
    vec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=interpret,
    )(step, p, g, m, v)
