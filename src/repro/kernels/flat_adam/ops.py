"""Jitted wrapper for the fused flat Adam kernel."""
from __future__ import annotations

import jax

from .kernel import flat_adam

flat_adam_op = jax.jit(
    flat_adam,
    static_argnames=("lr", "beta1", "beta2", "eps", "weight_decay", "block",
                     "interpret"),
)
