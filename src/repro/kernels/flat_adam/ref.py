"""Pure-jnp oracle: repro.optim.flat.flat_adam_update re-exported with the
kernel's exact signature."""
from __future__ import annotations

import jax.numpy as jnp

from repro.optim.flat import flat_adam_update


def flat_adam_ref(p, g, m, v, step, *, lr, beta1=0.9, beta2=0.95, eps=1e-8,
                  weight_decay=0.0):
    if weight_decay:
        # decoupled weight decay folded the same way as the kernel
        p_new, m_new, v_new = flat_adam_update(
            p, g, m, v, step.reshape(())[None][0] if step.ndim else step,
            lr=lr, beta1=beta1, beta2=beta2, eps=eps,
        )
        return p_new - lr * weight_decay * p, m_new, v_new
    s = step.reshape(()) if step.ndim else step
    return flat_adam_update(p, g, m, v, s, lr=lr, beta1=beta1, beta2=beta2, eps=eps)
