"""Paged decode attention — Pallas TPU kernel.

One decode step against the block-table KV cache.  Grid = (B, Hk): each
program owns one lane's queries for one KV head — q (rep, D) — plus the
full pool stream for that head, the lane's block-table row, and its
length.  The inner ``fori_loop`` walks **only the mapped blocks the lane
can attend** (``length // bs + 1`` of them — dynamic bound), resolving
each logical block to its physical pool row through the table and
maintaining online-softmax state (m, l, acc) in fp32, so the gathered
(B, max_len) lane view the jnp reference materialises never exists.

Like the flash kernel, the pool rides in VMEM via BlockSpec (fine in
interpret mode and for smoke pools; a production TPU deployment would
keep the pool in HBM and DMA blocks — noted in docs/serving.md).  MXU
alignment wants rep*D and bs*D in 128-multiples on real hardware;
correctness holds for any size in interpret mode, which is what CI
validates against the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _paged_kernel(
    len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref, *,
    bs: int, nb: int, window: int, softcap: float, scale: float,
):
    # unit slices (not bare ints): bare-int ref indices don't normalize on
    # older Pallas interpret mode
    q = q_ref[pl.ds(0, 1), pl.ds(0, 1)][0, 0].astype(jnp.float32) * scale
    rep, d = q.shape
    length = pl.load(len_ref, (pl.ds(0, 1), pl.ds(0, 1)))[0, 0]

    # blocks this lane attends: positions [0, length] -> length//bs + 1
    hi = jnp.minimum(length // bs + 1, nb)

    def body(j, carry):
        m, l, acc = carry
        blk = pl.load(tab_ref, (pl.ds(0, 1), pl.ds(j, 1)))[0, 0]
        k = pl.load(
            k_ref, (pl.ds(blk, 1), pl.ds(0, bs), pl.ds(0, 1), slice(None))
        )[0, :, 0].astype(jnp.float32)                       # (bs, d)
        v = pl.load(
            v_ref, (pl.ds(blk, 1), pl.ds(0, bs), pl.ds(0, 1), slice(None))
        )[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # (rep, bs)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (rep, bs), 1)
        ok = pos <= length
        if window:
            ok &= pos > length - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((rep,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep,), jnp.float32)
    a0 = jnp.zeros((rep, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[pl.ds(0, 1), pl.ds(0, 1)] = out.astype(o_ref.dtype)[None, None]


def paged_attention_fwd(
    q, k_pool, v_pool, lengths, tables, *,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool | None = None,
):
    """q: (B, Hk, rep, D); pools: (NB, bs, Hk, D); lengths: (B,) int32;
    tables: (B, nb) int32.  Returns (B, Hk, rep, D)."""
    B, Hk, rep, D = q.shape
    NB, bs = k_pool.shape[:2]
    nb = tables.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _paged_kernel,
        bs=bs, nb=nb, window=window, softcap=softcap, scale=D ** -0.5,
    )
    grid = (B, Hk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),           # lengths (B, 1)
            pl.BlockSpec((1, nb), lambda b, h: (b, 0)),          # tables
            pl.BlockSpec((1, 1, rep, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h: (0, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, rep, D), q.dtype),
        interpret=interpret,
    )(lengths[:, None], tables, q, k_pool, v_pool)
