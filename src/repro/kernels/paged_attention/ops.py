"""Jitted public wrapper for the paged decode-attention kernel.

Decode-only (no backward: serving never differentiates through the KV
cache), so unlike the flash wrapper there is no custom_vjp — just a jit
with the masking knobs static.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import paged_attention_fwd


@partial(jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_attention(
    q, k_pool, v_pool, lengths, tables, *,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool | None = None,
):
    """q: (B, Hk, rep, D); pools: (NB, bs, Hk, D); lengths: (B,) int32;
    tables: (B, nb) int32 block-table rows.  Returns (B, Hk, rep, D)."""
    return paged_attention_fwd(
        q, k_pool, v_pool, lengths, tables,
        window=window, softcap=softcap, interpret=interpret,
    )
