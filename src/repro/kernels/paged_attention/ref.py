"""Pure-jnp oracle for the paged decode-attention kernel.

Gathers each lane's blocks into logical order and runs the masked softmax
— the memory-expensive path the kernel avoids (the kernel walks the block
table and only ever holds one block in VMEM).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0e38


def paged_attention_ref(q, k_pool, v_pool, lengths, tables, *,
                        window: int = 0, softcap: float = 0.0):
    """q: (B, Hk, rep, D); pools: (NB, bs, Hk, D); lengths: (B,);
    tables: (B, nb).  Returns (B, Hk, rep, D)."""
    bs = k_pool.shape[1]
    B, nb = tables.shape

    def gather(pool):
        g = jnp.take(pool, tables, axis=0)              # (B, nb, bs, Hk, D)
        return g.reshape(B, nb * bs, *pool.shape[2:])

    k, v = gather(k_pool), gather(v_pool)
    pos = jnp.arange(nb * bs)
    valid = pos[None, :] <= lengths[:, None]
    if window:
        valid &= pos[None, :] > lengths[:, None] - window
    s = jnp.einsum(
        "bhrd,bshd->bhrs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (q.shape[-1] ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhrs,bshd->bhrd", p, v.astype(jnp.float32))
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
