"""Fused RMSNorm (+ optional residual add) — Pallas TPU kernel.

Grid over row tiles of the flattened (rows, D) input; one VMEM block of
(block_rows, D) per program.  Mean-square in fp32, (1 + gamma) scaling
(the repo-wide convention: gamma is zero-initialised).  Fusing the
residual add saves one full HBM round-trip of the residual stream per
block — the traffic the §Roofline memory term charges at op granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = (y * (1.0 + g)[None, :]).astype(o_ref.dtype)


def _rmsnorm_add_kernel(x_ref, r_ref, g_ref, o_ref, s_ref, *, eps: float):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = (y * (1.0 + g)[None, :]).astype(o_ref.dtype)
    s_ref[...] = s.astype(s_ref.dtype)


def rmsnorm(x, gamma, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    """x: (..., D); gamma: (D,).  Returns rmsnorm(x) * (1 + gamma)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    D = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    br = block_rows
    while rows % br:
        br //= 2
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out.reshape(shape)


def rmsnorm_add(x, residual, gamma, *, eps: float = 1e-6,
                block_rows: int = 256, interpret: bool | None = None):
    """Fused (x + residual) -> rmsnorm.  Returns (normed, new_residual)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    D = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    br = block_rows
    while rows % br:
        br //= 2
    normed, summed = pl.pallas_call(
        functools.partial(_rmsnorm_add_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, D), x.dtype),
            jax.ShapeDtypeStruct((rows, D), x.dtype),
        ],
        interpret=interpret,
    )(x.reshape(rows, D), residual.reshape(rows, D), gamma)
    return normed.reshape(shape), summed.reshape(shape)
