"""Jitted wrappers for the fused RMSNorm kernels."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm, rmsnorm_add

rmsnorm_op = jax.jit(rmsnorm, static_argnames=("eps", "block_rows", "interpret"))
rmsnorm_add_op = jax.jit(
    rmsnorm_add, static_argnames=("eps", "block_rows", "interpret")
)
