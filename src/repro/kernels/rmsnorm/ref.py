"""Pure-jnp oracle for the fused RMSNorm kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rmsnorm_add_ref(x, residual, gamma, eps: float = 1e-6):
    s = x.astype(jnp.float32) + residual.astype(jnp.float32)
    return rmsnorm_ref(s, gamma, eps), s.astype(x.dtype)
