"""Mamba2 SSD chunk scan — Pallas TPU kernel.

Grid = (B, H): each program owns one (batch, head) stream.  The SSM state
(N x P) lives in fp32 VMEM scratch and is carried across chunks by an
in-kernel ``fori_loop``; each chunk step is three MXU matmuls (C B^T
scores, intra-chunk combine, state inject) — the paper's GPU kernel is a
fused recurrent scan; on TPU the chunked matmul decomposition is the
MXU-native adaptation (DESIGN.md §2).

B/C group tensors are indexed per head via the BlockSpec index_map
(h -> h // heads_per_group): no (B,T,H,N) expansion is materialised.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
                chunk: int, seq: int):
    h = pl.program_id(1)
    P = x_ref.shape[-1]
    N = b_ref.shape[-1]
    nc = seq // chunk
    A = a_ref[0]                                         # scalar decay rate

    state_ref[...] = jnp.zeros_like(state_ref)

    def chunk_step(ci, _):
        # unit slices (not bare ints): bare-int ref indices don't normalize
        # on older Pallas interpret mode
        u = pl.ds(0, 1)
        sl = pl.ds(ci * chunk, chunk)
        x = pl.load(x_ref, (u, u, sl, slice(None)))[0, 0].astype(jnp.float32)   # (Q,P)
        dt = pl.load(dt_ref, (u, u, sl))[0, 0].astype(jnp.float32)              # (Q,)
        Bm = pl.load(b_ref, (u, u, sl, slice(None)))[0, 0].astype(jnp.float32)  # (Q,N)
        Cm = pl.load(c_ref, (u, u, sl, slice(None)))[0, 0].astype(jnp.float32)

        la = dt * A                                      # (Q,) log decay
        cum = jnp.cumsum(la)                             # inclusive
        seg = cum[-1]
        xdt = x * dt[:, None]

        # intra-chunk: scores[q,k] = C_q.B_k * exp(cum_q - cum_k), k <= q
        scores = jax.lax.dot_general(
            Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                # (Q,Q)
        decay = jnp.exp(cum[:, None] - cum[None, :])
        mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
            jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        scores = jnp.where(mask, scores * decay, 0.0)
        y = jax.lax.dot_general(
            scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                                # (Q,P)

        # inter-chunk: y += (C * exp(cum)) @ S_prev
        S = state_ref[...]
        y = y + jax.lax.dot_general(
            Cm * jnp.exp(cum)[:, None], S, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        # state update: S = exp(seg) * S + sum_k exp(seg - cum_k) B_k xdt_k^T
        w = jnp.exp(seg - cum)
        S_new = S * jnp.exp(seg) + jax.lax.dot_general(
            Bm * w[:, None], xdt, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        state_ref[...] = S_new
        pl.store(o_ref, (u, u, sl, slice(None)), y.astype(o_ref.dtype)[None, None])
        return ()

    jax.lax.fori_loop(0, nc, chunk_step, ())


def ssd_fwd(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool | None = None):
    """x: (B, H, T, P); dt: (B, H, T); A: (H,); Bm/Cm: (B, G, T, N).

    Returns y (B, H, T, P).  T must be divisible by chunk.
    """
    B, H, T, P = x.shape
    G, N = Bm.shape[1], Bm.shape[-1]
    rep = H // G
    while T % chunk:
        chunk //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(_ssd_kernel, chunk=chunk, seq=T)
    return pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, T, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1,), lambda b, h: (h,)),
            pl.BlockSpec((1, 1, T, N), lambda b, h: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, T, N), lambda b, h: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, P), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
