"""Jitted wrapper for the SSD kernel (model layout adapters)."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_fwd

ssd_op = jax.jit(ssd_fwd, static_argnames=("chunk", "interpret"))


def ssd_model_layout(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret=None):
    """models/ssm.py layout: x (B,T,H,P), dt (B,T,H), Bm/Cm (B,T,G,N)."""
    y = ssd_op(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
        Bm.transpose(0, 2, 1, 3), Cm.transpose(0, 2, 1, 3),
        chunk=chunk, interpret=interpret,
    )
    return y.transpose(0, 2, 1, 3)
