"""Pure-jnp oracle for the SSD kernel: step-by-step SSM recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm):
    """x: (B, H, T, P); dt: (B, H, T); A: (H,); Bm/Cm: (B, G, T, N)."""
    B, H, T, P = x.shape
    G, N = Bm.shape[1], Bm.shape[-1]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)   # (B,H,T,N)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, bt, ct = inp       # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dtt * A)
        S = S * decay[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt * dtt[..., None]
        )
        y = jnp.einsum("bhn,bhnp->bhp", ct, S)
        return S, y

    S0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, S0,
        (xf.transpose(2, 0, 1, 3), dtf.transpose(2, 0, 1),
         Bh.transpose(2, 0, 1, 3), Ch.transpose(2, 0, 1, 3)),
    )
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)
