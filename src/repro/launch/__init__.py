from .mesh import local_mesh, make_production_mesh, single_device_mesh

__all__ = ["local_mesh", "make_production_mesh", "single_device_mesh"]
