import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at backend init, and the production meshes need 512 host devices.

Usage:
    python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun

Each cell writes ``<out>/<arch>__<shape>__<mesh>[__faithful].json`` with
memory_analysis, cost_analysis, collective stats and the roofline terms.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models.common import ShardRules
from repro.optim import OptConfig
from repro.roofline import summarize_cell
from repro.serve.step import jit_decode_step, jit_prefill
from repro.train.step import TrainSettings, jit_train_step

# paper §5.1: input slicing is the OOM-avoidance knob; per-arch defaults
# chosen so the train_4k activations fit 16 GB/chip (see EXPERIMENTS.md).
TRAIN_SLICES = {
    "deepseek-67b": 8,
    "internvl2-76b": 8,
    "qwen3-moe-235b-a22b": 16,
    "gemma2-27b": 4,
    "stablelm-12b": 4,
    "qwen3-moe-30b-a3b": 4,
    "smollm-360m": 4,
    "whisper-tiny": 4,
    "zamba2-1.2b": 8,
    "xlstm-1.3b": 8,
}

# sequence-parallel residual stream only helps attention-family archs;
# SSM/recurrent blocks shard their head/channel dims instead (DESIGN.md).
NO_SP = ("hybrid", "ssm")


def cell_name(arch: str, shape: str, mesh: str, faithful: bool,
              variants: tuple[str, ...] = ()) -> str:
    n = f"{arch}__{shape}__{mesh}"
    if faithful:
        n += "__faithful"
    if variants:
        n += "__v-" + "-".join(variants)
    return n


def make_rules(mesh, cfg, faithful: bool) -> ShardRules:
    rules = ShardRules.for_mesh(mesh, faithful=faithful)
    if cfg.family in NO_SP:
        rules = dataclasses.replace(rules, sp=False)
    return rules


def serving_config(cfg):
    """Serving stores parameters in bf16 (no optimizer aboard)."""
    return dataclasses.replace(cfg, param_dtype="bfloat16")


def apply_variants(cfg, rules, settings_kw: dict, variants: tuple[str, ...]):
    """Named hillclimb variants (EXPERIMENTS.md §Perf):

    pure_dp     — no tensor parallelism: every mesh axis is data-parallel
                  (the paper's native mode; optimal when the model fits a chip)
    bf16_params — store parameters in bf16 (fp32 Adam moments = master)
    remat_dots  — checkpoint policy saves matmul outputs (skip bwd recompute)
    accum_bf16  — bf16 microbatch gradient accumulator
    moe_cf10    — MoE capacity factor 1.0 (smaller dispatch buffers)
    """
    for v in variants:
        if v == "pure_dp":
            rules = dataclasses.replace(
                rules, dp=tuple(rules.mesh.axis_names), tp=None,
                fsdp="data", sp=False)
        elif v == "bf16_params":
            cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        elif v == "remat_dots":
            settings_kw["remat"] = "dots"
        elif v == "remat_none":
            settings_kw["remat"] = False
        elif v == "accum_bf16":
            settings_kw["accum_dtype"] = "bfloat16"
        elif v == "opt_scan":
            settings_kw["opt_chunked"] = True
        elif v == "moe_cf10":
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
        elif v:
            raise ValueError(f"unknown variant {v!r}")
    return cfg, rules, settings_kw


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             faithful: bool = False, num_slices: int | None = None,
             variants: tuple[str, ...] = ()) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = make_rules(mesh, cfg, faithful)
    settings_kw: dict = {}
    cfg, rules, settings_kw = apply_variants(cfg, rules, settings_kw, variants)

    t0 = time.perf_counter()
    if shape.kind == "train":
        k = num_slices if num_slices is not None else TRAIN_SLICES.get(arch, 1)
        # each microbatch must still cover every data-parallel shard
        ndp = 1
        for a in rules.dp:
            ndp *= mesh.shape[a]
        k = max(1, min(k, shape.global_batch // max(ndp, 1)))
        opt_chunked = settings_kw.pop("opt_chunked", False)
        settings = TrainSettings(num_slices=k, faithful=faithful, **settings_kw)
        jitted, (p_sds, o_sds, b_sds), _ = jit_train_step(
            cfg, mesh, rules, OptConfig(kind="adam", chunked=opt_chunked),
            shape, settings
        )
        lowered = jitted.lower(p_sds, o_sds, b_sds)
    elif shape.kind == "prefill":
        scfg = serving_config(cfg)
        jitted, (p_sds, tok_sds, e_sds) = jit_prefill(
            scfg, mesh, rules, shape, max_len=shape.seq_len
        )
        lowered = jitted.lower(p_sds, tok_sds, e_sds)
    else:  # decode
        scfg = serving_config(cfg)
        jitted, (p_sds, cache_sds, tok_sds, idx_sds) = jit_decode_step(
            scfg, mesh, rules, shape
        )
        lowered = jitted.lower(p_sds, cache_sds, tok_sds, idx_sds)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    out = summarize_cell(cfg, shape, cost, mem, hlo, n_chips)
    out.update({
        "mesh": "multi" if multi_pod else "single",
        "faithful": faithful,
        "variants": list(variants),
        "num_slices": num_slices if num_slices is not None else TRAIN_SLICES.get(arch, 1),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    })
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--faithful", action="store_true",
                    help="paper-faithful replicated-parameter DP baseline")
    ap.add_argument("--variant", default="",
                    help="comma-joined hillclimb variants (see apply_variants)")
    ap.add_argument("--slices", type=int, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    variants = tuple(v for v in args.variant.split(",") if v)
    failures = 0
    for arch, shape, m in cells:
        name = cell_name(arch, shape, m, args.faithful, variants)
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {name}: exists, skipping")
            continue
        print(f"[dryrun] {name}: lowering...", flush=True)
        try:
            res = run_cell(arch, shape, m == "multi",
                           faithful=args.faithful, num_slices=args.slices,
                           variants=variants)
        except Exception as e:
            failures += 1
            res = {"arch": arch, "shape": shape, "mesh": m,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"[dryrun] {name}: FAILED {type(e).__name__}: {e}", flush=True)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if "error" not in res:
            if res.get("skipped"):
                print(f"[dryrun] {name}: skipped ({res['skipped']})")
            else:
                t = res["terms"]
                print(
                    f"[dryrun] {name}: ok compile={res['compile_s']}s "
                    f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
                    f"coll={t['collective_s']:.4f}s dom={t['dominant']} "
                    f"peak={res.get('memory', {}).get('peak_estimate_bytes', 0)/2**30:.2f}GiB",
                    flush=True,
                )
        jax.clear_caches()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
