"""Mesh construction for the production pods and local development.

``make_production_mesh`` is a FUNCTION (not module-level state) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls it.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh


def _mk(shape, axes) -> Mesh:
    return make_mesh(
        tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data x model).
    Multi-pod: 2x16x16 = 512 chips (pod x data x model); the ``pod`` axis
    is the cross-pod (DCN) data-parallel axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def single_device_mesh() -> Mesh:
    """All production axes present with size 1 — used by CPU smoke tests so
    every PartitionSpec in the model code resolves."""
    return _mk((1, 1, 1), ("pod", "data", "model"))


def local_mesh(data: int | None = None, model: int = 1) -> Mesh:
    """Development mesh over however many local devices exist."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return _mk((data, model), ("data", "model"))
