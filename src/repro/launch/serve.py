"""Serving launcher: prefill + batched decode with sequence-sharded caches.

    python -m repro.launch.serve --arch gemma2-27b --smoke --batch 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import local_mesh, make_production_mesh, single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.serve import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", choices=("production", "local", "single"),
                    default="single")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    mesh = {"production": make_production_mesh,
            "local": local_mesh,
            "single": single_device_mesh}[args.mesh]()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rules = ShardRules.for_mesh(mesh)
    mod = registry.get_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "vlm":
        extra = rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        extra = rng.normal(size=(args.batch, cfg.enc_seq,
                                 cfg.d_model)).astype(np.float32)
    out = generate(cfg, mesh, rules, params, prompts, extra,
                   ServeConfig(max_new_tokens=args.new_tokens,
                               temperature=args.temperature))
    for i, row in enumerate(out):
        print(f"seq{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
