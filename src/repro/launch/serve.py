"""Serving launcher.

Continuous-batching engine under a Poisson request stream (the default):

    python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 16 --rate 20 --max-slots 8

The engine serves every slot-capable family — lm KV caches and the
recurrent state kinds alike (xlstm's per-lane recurrent state, zamba's
composed hybrid cache):

    python -m repro.launch.serve --arch xlstm-1.3b --smoke --requests 8
    python -m repro.launch.serve --arch zamba2-1.2b --smoke --requests 8

The paged-layout knobs (--kv-layout paged, --prefill-chunk,
--prefix-cache, --admission preempt) are KV-only: recurrent state is
O(1) in sequence length, so there is no seq axis to page.

Legacy static batch (one fixed batch to completion):

    python -m repro.launch.serve --arch gemma2-27b --smoke --engine static
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import local_mesh, make_production_mesh, single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.serve import (
    FAULT_SITES, EngineConfig, FaultPlan, ServeConfig, ServeEngine,
    generate_static,
)


def run_static(cfg, mesh, rules, params, args, rng):
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "vlm":
        extra = rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        extra = rng.normal(size=(args.batch, cfg.enc_seq,
                                 cfg.d_model)).astype(np.float32)
    out = generate_static(cfg, mesh, rules, params, prompts, extra,
                          ServeConfig(max_new_tokens=args.new_tokens,
                                      temperature=args.temperature))
    for i, row in enumerate(out):
        print(f"seq{i}: {row.tolist()}")


def run_stream(cfg, mesh, rules, params, args, rng):
    """Drive the continuous-batching engine with a Poisson arrival trace."""
    kind = registry.state_kind(cfg)
    if args.kv_layout == "paged" and kind != "kv":
        raise SystemExit(
            f"--kv-layout paged: family {cfg.family!r} has state kind "
            f"{kind!r} — recurrent state has no seq axis to page; "
            "drop the flag to serve on the slotted layout")
    max_len = args.prompt_len + args.new_tokens + 8
    if args.kv_layout == "paged":
        max_len = -(-max_len // args.page_size) * args.page_size
    faults = None
    if args.chaos_rate > 0:
        faults = FaultPlan(args.chaos_seed,
                           {site: args.chaos_rate for site in FAULT_SITES})
    engine = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(
            max_slots=args.max_slots,
            max_len=max_len,
            seed=args.seed,
            kv_layout=args.kv_layout,
            page_size=args.page_size,
            num_blocks=args.num_blocks,
            prefill_chunk=args.prefill_chunk,
            prefix_cache=args.prefix_cache,
            admission=args.admission,
            max_retries=args.max_retries,
        ),
        faults=faults,
    )
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    prompts = [
        rng.integers(0, cfg.vocab, rng.integers(2, args.prompt_len + 1))
        .astype(np.int32)
        for _ in range(args.requests)
    ]
    budgets = rng.integers(1, args.new_tokens + 1, args.requests)

    t0 = time.perf_counter()
    i = 0
    while i < len(prompts) or engine.has_work():
        now = time.perf_counter() - t0
        while i < len(prompts) and arrivals[i] <= now:
            engine.submit(prompts[i], max_new_tokens=int(budgets[i]),
                          temperature=args.temperature, rid=i,
                          deadline_s=args.deadline_s)
            i += 1
        if not engine.step() and i < len(prompts):
            time.sleep(max(0.0, t0 + arrivals[i] - time.perf_counter()))
    wall = time.perf_counter() - t0

    tokens = 0
    for rid in range(len(prompts)):
        c = engine.completions[rid]
        tokens += len(c.tokens)
        lat = (f"{(c.finish_time - c.submit_time) / len(c.tokens) * 1e3:.1f}"
               " ms/tok" if c.tokens else "-")
        note = f"  [{c.error}]" if c.error else ""
        print(f"req{rid}: {c.status:9s} plen={c.prompt_len} "
              f"new={len(c.tokens)} {lat}  {c.tokens}{note}")
    print(f"-- {tokens} tokens in {wall:.2f}s = {tokens / wall:.1f} tok/s")
    print(f"-- state[{engine.stats['state_kind']}/{args.kv_layout}]: "
          f"{engine.stats['kv_peak_used_bytes'] / 2**20:.2f} MiB peak used / "
          f"{engine.kv_reserved_bytes / 2**20:.2f} MiB reserved")
    if args.kv_layout == "paged":
        s = engine.stats
        print(f"-- prefix cache: hit_rate {s['prefix_hit_rate']:.2f} "
              f"({s['prefix_hit_tokens']}/{s['prefix_lookup_tokens']} tokens, "
              f"{s['cow_copies']} COW)  preemptions {s['preemptions']} "
              f"(resumed {s['resumed']})")
    s = engine.stats
    print(f"-- status: ok {s['status_ok']} timeout {s['status_timeout']} "
          f"cancelled {s['status_cancelled']} failed {s['status_failed']}  "
          f"retries {s['retries']}")
    if faults is not None:
        print(f"-- chaos[seed {args.chaos_seed}]: injected "
              f"{s['faults_injected']} detected {s['faults_detected']}  "
              f"{faults.stats()}")
    print(f"-- stats: {engine.stats}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", choices=("production", "local", "single"),
                    default="single")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # request-stream knobs (continuous engine)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    # KV layout knobs (continuous engine)
    ap.add_argument("--kv-layout", choices=("slotted", "paged"),
                    default="slotted")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV block size (paged layout)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks (paged; default worst case)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: admit prompts in chunks of this many tokens "
                         "interleaved with decode (paged only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted shared-prefix block reuse (paged): "
                         "repeated prompt prefixes skip prefill")
    # robustness knobs (continuous engine)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request TTL from submission; expired requests "
                         "finish with status 'timeout' keeping emitted "
                         "tokens")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="bounded retries (preempt-and-replay) before a "
                         "faulting request terminates 'failed'")
    ap.add_argument("--chaos-rate", type=float, default=0.0,
                    help=">0: inject seeded faults at every fault site "
                         "with this per-consult probability (exercises "
                         "quarantine + retry recovery)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="FaultPlan seed (reproducible fault schedules)")
    ap.add_argument("--admission", choices=("deficit", "preempt"),
                    default="deficit",
                    help="deficit: gate admission on worst-case block "
                         "commitments; preempt: run the pool near full and "
                         "evict-and-requeue the lowest-priority lane when "
                         "decode growth finds it empty (paged only)")
    args = ap.parse_args()

    mesh = {"production": make_production_mesh,
            "local": local_mesh,
            "single": single_device_mesh}[args.mesh]()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rules = ShardRules.for_mesh(mesh)
    mod = registry.get_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    if args.engine == "continuous" and registry.supports_slot_serving(cfg):
        run_stream(cfg, mesh, rules, params, args, rng)
    else:
        if args.engine == "continuous":
            print(f"# family {cfg.family!r} has no slot-serving support; "
                  "falling back to the static loop")
        run_static(cfg, mesh, rules, params, args, rng)


if __name__ == "__main__":
    main()
