"""Serving launcher.

Continuous-batching engine(s) behind the router front-end under a
Poisson request stream (the default):

    python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 16 --rate 20 --max-slots 8

Multi-replica serving with crash failover, load shedding, and
zero-downtime drain (serve/router.py):

    python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 32 --replicas 3 --drain-at 8 --shed-queue-depth 16

The engine serves every slot-capable family — lm KV caches and the
recurrent state kinds alike (xlstm's per-lane recurrent state, zamba's
composed hybrid cache):

    python -m repro.launch.serve --arch xlstm-1.3b --smoke --requests 8
    python -m repro.launch.serve --arch zamba2-1.2b --smoke --requests 8

The paged-layout knobs (--kv-layout paged, --prefill-chunk,
--prefix-cache, --admission preempt) are KV-only: recurrent state is
O(1) in sequence length, so there is no seq axis to page.

Legacy static batch (one fixed batch to completion):

    python -m repro.launch.serve --arch gemma2-27b --smoke --engine static
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import local_mesh, make_production_mesh, single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.obs import Observer, merged_histogram, to_chrome_trace, validate
from repro.serve import (
    ENGINE_FAULT_SITES, REPLICA_FAULT_SITES, STATUSES, EngineConfig,
    FaultPlan, Router, RouterConfig, ServeConfig, generate_static,
)


def run_static(cfg, mesh, rules, params, args, rng):
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = None
    if cfg.family == "vlm":
        extra = rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        extra = rng.normal(size=(args.batch, cfg.enc_seq,
                                 cfg.d_model)).astype(np.float32)
    out = generate_static(cfg, mesh, rules, params, prompts, extra,
                          ServeConfig(max_new_tokens=args.new_tokens,
                                      temperature=args.temperature))
    for i, row in enumerate(out):
        print(f"seq{i}: {row.tolist()}")


def _print_latency_summary(router):
    """Per-status latency table: p50/p99 time-to-first-token and
    per-token latency, one row per terminal status that occurred.

    Consumes the shared ``ttft_ms_<status>`` / ``tpot_ms_<status>``
    histograms (obs/metrics.py) merged across the router registry and
    every replica engine's registry — the same mergeable sketches the
    bench snapshot embeds, not a hand-rolled percentile pass over raw
    completion timestamps."""
    regs = [router.obs.metrics] + [h.engine.obs.metrics
                                   for h in router.replicas]
    rs = router.stats
    print("-- latency by status (p50/p99 ms):")
    for status in STATUSES:
        n = rs.get(f"status_{status}", 0)
        if not n:
            continue
        ttft = merged_histogram(f"ttft_ms_{status}", regs)
        tpot = merged_histogram(f"tpot_ms_{status}", regs)
        fmt = lambda h: (f"{h.quantile(0.50):8.1f}/{h.quantile(0.99):8.1f}"
                         if h.count else "       -/       -")
        print(f"   {status:9s} n={n:4d}  "
              f"ttft {fmt(ttft)}  per-token {fmt(tpot)}")


def run_stream(cfg, mesh, rules, params, args, rng):
    """Drive N engine replicas behind the router front-end with a
    Poisson arrival trace (``--replicas 1`` is a plain engine with the
    router's admission queue in front)."""
    kind = registry.state_kind(cfg)
    if args.kv_layout == "paged" and kind != "kv":
        raise SystemExit(
            f"--kv-layout paged: family {cfg.family!r} has state kind "
            f"{kind!r} — recurrent state has no seq axis to page; "
            "drop the flag to serve on the slotted layout")
    if args.drain_at is not None and args.replicas < 2:
        raise SystemExit("--drain-at needs --replicas >= 2 (draining the "
                         "only replica leaves nothing to migrate onto)")
    max_len = args.prompt_len + args.new_tokens + 8
    if args.kv_layout == "paged":
        max_len = -(-max_len // args.page_size) * args.page_size
    faults = None
    if args.replica_chaos_rate > 0:
        faults = FaultPlan(
            args.chaos_seed,
            {site: args.replica_chaos_rate for site in REPLICA_FAULT_SITES})
    engine_faults = None
    if args.chaos_rate > 0:
        engine_faults = [
            FaultPlan(args.chaos_seed + 1 + i,
                      {site: args.chaos_rate for site in ENGINE_FAULT_SITES})
            for i in range(args.replicas)
        ]
    draft_params = None
    if args.spec_k > 0:
        # a same-architecture draft nudged away from the target: cheap to
        # stand up and accepts often enough to demo multi-token commits
        # (real deployments pass trained draft weights)
        mod = registry.get_module(cfg)
        noise = mod.init(cfg, jax.random.PRNGKey(args.seed + 1))
        a = args.spec_draft_alpha
        draft_params = jax.tree.map(lambda p, n: (1 - a) * p + a * n,
                                    params, noise)
    obs = None
    if args.trace_out or args.flightrec_dir:
        # full flight: tracer + ring-buffer recorder (invariant failures
        # dump to --flightrec-dir); metrics are always on either way
        obs = Observer.full(dump_dir=args.flightrec_dir or ".",
                            name="router")
    router = Router(
        cfg, mesh, rules, params,
        EngineConfig(
            max_slots=args.max_slots,
            max_len=max_len,
            seed=args.seed,
            kv_layout=args.kv_layout,
            page_size=args.page_size,
            num_blocks=args.num_blocks,
            prefill_chunk=args.prefill_chunk,
            prefix_cache=args.prefix_cache,
            admission=args.admission,
            max_retries=args.max_retries,
            spec_draft=cfg if args.spec_k > 0 else None,
            spec_k=args.spec_k,
        ),
        RouterConfig(replicas=args.replicas,
                     shed_queue_depth=args.shed_queue_depth),
        faults=faults,
        engine_faults=engine_faults,
        obs=obs,
        draft_params=draft_params,
    )
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    prompts = [
        rng.integers(0, cfg.vocab, rng.integers(2, args.prompt_len + 1))
        .astype(np.int32)
        for _ in range(args.requests)
    ]
    budgets = rng.integers(1, args.new_tokens + 1, args.requests)

    t0 = time.perf_counter()
    i = 0
    drained = False
    while i < len(prompts) or router.has_work():
        now = time.perf_counter() - t0
        while i < len(prompts) and arrivals[i] <= now:
            router.submit(prompts[i], max_new_tokens=int(budgets[i]),
                          temperature=args.temperature, rid=i,
                          deadline_s=args.deadline_s)
            i += 1
        if (args.drain_at is not None and not drained
                and len(router.completions) >= args.drain_at):
            idx = args.replicas - 1
            moved = router.drain(idx)
            print(f"-- drained replica {idx}: migrated {moved} in-flight "
                  "requests to survivors")
            drained = True
        if not router.step() and i < len(prompts):
            time.sleep(max(0.0, t0 + arrivals[i] - time.perf_counter()))
    wall = time.perf_counter() - t0

    tokens = 0
    for rid in range(len(prompts)):
        c = router.completions[rid]
        tokens += len(c.tokens)
        lat = (f"{(c.finish_time - c.submit_time) / len(c.tokens) * 1e3:.1f}"
               " ms/tok" if c.tokens else "-")
        note = f"  [{c.error}]" if c.error else ""
        where = router.placements.get(rid)
        place = f"r{where}" if where is not None else "--"
        print(f"req{rid}: {c.status:9s} {place} plen={c.prompt_len} "
              f"new={len(c.tokens)} {lat}  {c.tokens}{note}")
    print(f"-- {tokens} tokens in {wall:.2f}s = {tokens / wall:.1f} tok/s "
          f"across {args.replicas} replica(s)")
    for h in router.replicas:
        s = h.engine.stats
        line = (f"-- replica {h.idx} [{h.state}] "
                f"state[{s['state_kind']}/{args.kv_layout}]: "
                f"{s['kv_peak_used_bytes'] / 2**20:.2f} MiB peak used / "
                f"{h.engine.kv_reserved_bytes / 2**20:.2f} MiB reserved")
        if args.kv_layout == "paged":
            line += (f"  prefix hit_rate {s['prefix_hit_rate']:.2f} "
                     f"preempt {s['preemptions']} resume {s['resumed']}")
        if args.spec_k > 0:
            line += (f"  spec accept {s['spec_acceptance_rate']:.2f} "
                     f"tok/round {s['tokens_per_decode_dispatch']:.2f}")
        print(line)
    rs = router.stats
    print(f"-- status: ok {rs['status_ok']} timeout {rs['status_timeout']} "
          f"cancelled {rs['status_cancelled']} failed {rs['status_failed']} "
          f"shed {rs['status_shed']}  "
          f"failovers {rs['failovers']} migrated {rs['migrated']}")
    if faults is not None or engine_faults is not None:
        injected = sum(h.engine.stats["faults_injected"]
                       for h in router.replicas)
        print(f"-- chaos[seed {args.chaos_seed}]: engine faults {injected}  "
              f"replicas dead {rs['replicas_dead']} "
              f"stalls {rs['stalls_injected']}/{rs['stalls_detected']} "
              f"(injected/detected)")
    _print_latency_summary(router)
    if args.trace_out:
        ev = router.obs.tracer.events
        info = validate(ev)
        to_chrome_trace(ev, args.trace_out)
        print(f"-- trace: {info['events']} events / {info['spans']} spans / "
              f"{info['requests']} requests -> {args.trace_out} "
              "(load in chrome://tracing or ui.perfetto.dev)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", choices=("production", "local", "single"),
                    default="single")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # request-stream knobs (continuous engine)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    # router front-end knobs (continuous engine)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the router (shared AOT "
                         "cache + weights; crash failover between them)")
    ap.add_argument("--shed-queue-depth", type=int, default=64,
                    help="bounded admission queue: submissions beyond "
                         "this depth terminate with status 'shed'")
    ap.add_argument("--drain-at", type=int, default=None,
                    help="after this many completions, drain the last "
                         "replica (zero-downtime migration to survivors); "
                         "needs --replicas >= 2")
    # KV layout knobs (continuous engine)
    ap.add_argument("--kv-layout", choices=("slotted", "paged"),
                    default="slotted")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV block size (paged layout)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks (paged; default worst case)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: admit prompts in chunks of this many tokens "
                         "interleaved with decode (paged only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted shared-prefix block reuse (paged): "
                         "repeated prompt prefixes skip prefill")
    # robustness knobs (continuous engine)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request TTL from submission; expired requests "
                         "finish with status 'timeout' keeping emitted "
                         "tokens")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="bounded retries (preempt-and-replay) before a "
                         "faulting request terminates 'failed'")
    ap.add_argument("--chaos-rate", type=float, default=0.0,
                    help=">0: inject seeded faults at every per-engine "
                         "fault site with this per-consult probability "
                         "(exercises quarantine + retry recovery)")
    ap.add_argument("--replica-chaos-rate", type=float, default=0.0,
                    help=">0: inject seeded replica crashes/stalls at "
                         "this per-tick probability (exercises router "
                         "failover; pair with --replicas >= 2)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="FaultPlan seed (reproducible fault schedules)")
    # speculative decoding knobs (continuous engine)
    ap.add_argument("--spec-k", type=int, default=0,
                    help=">0: speculative decoding — a draft model "
                         "proposes this many tokens per lane, all "
                         "verified in one fused target dispatch; greedy "
                         "output is bitwise-unchanged")
    ap.add_argument("--spec-draft-alpha", type=float, default=0.1,
                    help="demo draft weights = (1-a)*target + a*fresh "
                         "init; smaller a = higher acceptance")
    # observability knobs (continuous engine)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the request/engine span timeline as a "
                         "Chrome-trace JSON (chrome://tracing, "
                         "ui.perfetto.dev)")
    ap.add_argument("--flightrec-dir", default=None, metavar="DIR",
                    help="arm the flight recorder: invariant failures "
                         "dump the last N events as flightrec_*.json "
                         "into this directory")
    ap.add_argument("--admission", choices=("deficit", "preempt"),
                    default="deficit",
                    help="deficit: gate admission on worst-case block "
                         "commitments; preempt: run the pool near full and "
                         "evict-and-requeue the lowest-priority lane when "
                         "decode growth finds it empty (paged only)")
    args = ap.parse_args()

    mesh = {"production": make_production_mesh,
            "local": local_mesh,
            "single": single_device_mesh}[args.mesh]()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rules = ShardRules.for_mesh(mesh)
    mod = registry.get_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    if args.engine == "continuous" and registry.supports_slot_serving(cfg):
        run_stream(cfg, mesh, rules, params, args, rng)
    else:
        if args.engine == "continuous":
            print(f"# family {cfg.family!r} has no slot-serving support; "
                  "falling back to the static loop")
        run_static(cfg, mesh, rules, params, args, rng)


if __name__ == "__main__":
    main()
