"""Production training launcher.

    python -m repro.launch.train --arch deepseek-67b --shape train_4k \
        --mesh production --steps 1000 --ckpt-dir /ckpts/run1

On real hardware the mesh axes map onto the pod topology; on the dev box
use ``--mesh local`` (all local devices) or ``--mesh single``.  Restart
the same command after a failure: the loop resumes from the newest
checkpoint and replays the deterministic data stream.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import local_mesh, make_production_mesh, single_device_mesh
from repro.models.common import ShardRules
from repro.obs import Observer, Tracer, to_chrome_trace
from repro.optim import OptConfig
from repro.train import LoopConfig, TrainSettings, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small shape (CPU dev)")
    ap.add_argument("--mesh", choices=("production", "multipod", "local", "single"),
                    default="local")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=("adam", "adamw", "momentum",
                                            "rmsprop", "sgd"), default="adam")
    ap.add_argument("--slices", type=int, default=1,
                    help="paper §5.1 input slicing (gradient accumulation)")
    ap.add_argument("--faithful", action="store_true",
                    help="paper-faithful replicated-parameter DP")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="profile the host side of the loop: per-step "
                         "stage_batch/h2d/dispatch/device_wait spans + a "
                         "step_ms histogram, written as Chrome-trace JSON "
                         "(adds one host sync per step; see "
                         "docs/observability.md)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="wrap the run in jax.profiler.start_trace/"
                         "stop_trace (device-side TensorBoard/Perfetto "
                         "trace); independent of --trace-out")
    args = ap.parse_args()

    if args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "single":
        mesh = single_device_mesh()
    else:
        mesh = local_mesh()

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        shape = ShapeConfig("smoke", "train", 64, 8)
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]

    rules = ShardRules.for_mesh(mesh, faithful=args.faithful)
    if cfg.family in ("hybrid", "ssm"):
        rules = dataclasses.replace(rules, sp=False)

    obs = Observer(tracer=Tracer(), name="train") if args.trace_out else None
    profiling = False
    if args.jax_profile:
        try:
            jax.profiler.start_trace(args.jax_profile)
            profiling = True
        except Exception as e:  # noqa: BLE001 - profiler is optional
            print(f"# jax profiler unavailable ({e}); continuing untraced")

    res = train(
        cfg, shape, mesh, rules,
        OptConfig(kind=args.optimizer, lr=args.lr),
        TrainSettings(num_slices=args.slices, faithful=args.faithful),
        LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                   ckpt_dir=args.ckpt_dir, seed=args.seed),
        obs=obs,
    )

    if profiling:
        jax.profiler.stop_trace()
        print(f"# jax profile written to {args.jax_profile}")
    if obs is not None:
        to_chrome_trace(obs.tracer.events, args.trace_out)
        hist = res["metrics"]["step_ms"]
        print(f"# step_ms p50/p99: {hist['p50']:.1f}/{hist['p99']:.1f} "
              f"over {hist['count']} steps -> trace {args.trace_out}")
    print(f"final loss: {res['final_loss']:.4f}")


if __name__ == "__main__":
    main()
