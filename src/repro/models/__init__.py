from . import attention, common, lm, moe, registry, ssm, whisper, xlstm, xlstm_lm, zamba
from .common import ShardRules
from .registry import abstract_params, get_module, param_pspecs

__all__ = [
    "attention", "common", "lm", "moe", "registry", "ssm", "whisper",
    "xlstm", "xlstm_lm", "zamba", "ShardRules",
    "abstract_params", "get_module", "param_pspecs",
]
