"""Attention: RoPE, chunked (flash-style) attention, distributed decode.

``chunked_attention`` is the portable XLA path: an online-softmax scan over
query/key chunks so the (S x S) score matrix is never materialised — the
same blocking the Pallas kernel (kernels/flash_attention) uses on TPU, and
the oracle it is tested against.

``decode_attention`` is the serving path: KV caches are sharded along the
*sequence* axis across the ``model`` (and, for batch-1 long-context, also
the ``data``/``pod``) mesh axes; each shard computes a partial softmax and
the results are combined with a log-sum-exp reduction (distributed
flash-decoding).  This is what makes 32k/500k-token caches fit.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding.  x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked attention (training / prefill)
# ---------------------------------------------------------------------------

def pick_chunk(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= target (attention chunk size)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return max(c, 1)


def _mask_scores(s, pos_q, pos_k, causal, window, kv_len):
    """s: (..., Q, K) fp32; pos_q: (Q,), pos_k: (K,)."""
    ok = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        ok &= pos_k[None, :] <= pos_q[:, None]
    if window:
        ok &= pos_k[None, :] > pos_q[:, None] - window
    if kv_len is not None:
        ok &= pos_k[None, :] < kv_len
    return jnp.where(ok, s, NEG_INF)


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 256,
    kv_chunk: int = 256,
    q_offset: int = 0,
    kv_len=None,
):
    """Memory-bounded attention.

    q: (B, Sq, H, D); k/v: (B, Sk, Hk, D) with H % Hk == 0 (GQA).
    Sliding-window causal attention uses a *static band* of KV chunks
    (exact, no wasted blocks); full attention scans all KV chunks with
    masking (the Pallas kernel skips masked blocks on TPU).
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    rep = H // Hk
    scale = D ** -0.5

    q_chunk = pick_chunk(Sq, q_chunk)
    kv_chunk = pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    qg = q.reshape(B, nq, q_chunk, Hk, rep, D).transpose(1, 0, 3, 4, 2, 5)
    # qg: (nq, B, Hk, rep, qc, D)
    kg = k.reshape(B, nk, kv_chunk, Hk, D).transpose(1, 0, 3, 2, 4)   # (nk,B,Hk,kc,D)
    vg = v.reshape(B, nk, kv_chunk, Hk, D).transpose(1, 0, 3, 2, 4)

    # the static band assumes q and kv blocks are aligned from position 0;
    # a (possibly traced) nonzero q_offset — chunked prefill resuming at a
    # mid-prompt position — falls back to the masked full scan
    aligned = isinstance(q_offset, (int, np.integer)) and q_offset == 0
    band = causal and window and window < Sk and q_chunk == kv_chunk and aligned
    # q-chunk rows [iC, iC+C-1] may attend keys in [iC - window + 1, iC + C - 1]
    # -> ceil((window + C - 1) / C) KV chunks ending at chunk i.
    nb = int(np.ceil((window + kv_chunk - 1) / kv_chunk)) if band else nk

    def q_step(_, inputs):
        qi, i = inputs
        pos_q = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, jn):
            m, l, acc = carry
            if band:
                off = jn
                j = jnp.maximum(i - off, 0)
                valid_chunk = (i - off) >= 0
            else:
                j = jn
                valid_chunk = True
            kj = jax.lax.dynamic_index_in_dim(kg, j, axis=0, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vg, j, axis=0, keepdims=False)
            pos_k = j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bhrqd,bhkd->bhrqk", qi.astype(jnp.float32), kj.astype(jnp.float32)
            ) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            s = _mask_scores(s, pos_q, pos_k, causal, window, kv_len)
            if band:
                s = jnp.where(valid_chunk, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhrqk,bhkd->bhrqd", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hk, rep, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # cast per-chunk: the stacked output (and any SPMD reshard of it)
        # stays in the compute dtype rather than f32
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    # outs: (nq, B, Hk, rep, qc, D) -> (B, Sq, H, D)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, D)


def reference_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                        q_offset: int = 0, kv_len=None):
    """Naive O(S^2)-memory oracle for tests."""
    B, Sq, H, D = q.shape
    _, Sk, Hk, _ = k.shape
    rep = H // Hk
    qf = q.astype(jnp.float32).reshape(B, Sq, Hk, rep, D)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos_q = q_offset + jnp.arange(Sq)
    pos_k = jnp.arange(Sk)
    s = _mask_scores(s, pos_q, pos_k, causal, window, kv_len)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Distributed decode (sequence-sharded KV cache, LSE combine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeSharding:
    """How the KV cache is laid out on the mesh for decoding."""

    mesh: Mesh
    batch_axes: tuple[str, ...]     # axes sharding the batch dim (may be empty)
    seq_axes: tuple[str, ...]       # axes sharding the cache sequence dim

    @classmethod
    def choose(cls, mesh: Mesh, batch: int) -> "DecodeSharding":
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tp = tuple(a for a in ("model",) if a in mesh.axis_names)
        ndp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        if batch % max(ndp, 1) == 0 and batch >= ndp:
            return cls(mesh, dp, tp)
        # batch too small (long-context, batch=1): spread the sequence over
        # every axis instead.
        return cls(mesh, (), dp + tp)

    def cache_spec(self) -> P:
        b = self.batch_axes or None
        s = self.seq_axes or None
        return P(b, s, None, None)     # (B, S, Hk, D)


def decode_attention(
    q, k_cache, v_cache, k_new, v_new, cur_index, *,
    sharding: DecodeSharding,
    window: int = 0,
    softcap: float = 0.0,
):
    """One decoding step against a sequence-sharded KV cache.

    q:            (B, Hk, rep, D) — current-token queries (RoPE applied)
    k_cache/v_cache: (B, S, Hk, D) — sharded per ``sharding.cache_spec()``
    k_new/v_new:  (B, Hk, D) — current token's K/V, written at ``cur_index``
    cur_index:    number of tokens already in the cache — scalar int32
                  (all sequences aligned, the classic batched-decode path)
                  or a ``(B,)`` vector (continuous batching: each slot is
                  at its own position; writes and validity masks are
                  per-row)

    Returns (out (B, Hk, rep, D), k_cache', v_cache').
    """
    mesh = sharding.mesh
    baxes, saxes = sharding.batch_axes, sharding.seq_axes
    S = k_cache.shape[1]
    n_seq = int(np.prod([mesh.shape[a] for a in saxes])) if saxes else 1
    s_loc = S // n_seq
    vec_index = jnp.ndim(cur_index) == 1

    def shard_fn(q, kc, vc, kn, vn, idx):
        # local shapes: q (Bl, Hk, rep, D); kc/vc (Bl, s_loc, Hk, D)
        if saxes:
            shard_id = jax.lax.axis_index(saxes)
        else:
            shard_id = jnp.int32(0)
        start = shard_id * s_loc
        pos = start + jnp.arange(s_loc)

        if vec_index:
            # per-slot positions: per-row scatter writes + per-row valid
            # masks.  The scatter touches only the Bl written rows — a
            # one-hot select would rewrite the whole (Bl, s_loc, Hk, D)
            # cache (the dominant decode tensor) every step.
            rel = idx - start                              # (Bl,)
            in_range = (rel >= 0) & (rel < s_loc)
            rows = jnp.arange(rel.shape[0])
            safe = jnp.clip(rel, 0, s_loc - 1)

            def write(c, new):
                keep = c[rows, safe]                       # (Bl, Hk, D)
                val = jnp.where(
                    in_range[:, None, None], new.astype(c.dtype), keep)
                return c.at[rows, safe].set(val)

            valid = pos[None, :] <= idx[:, None]           # (Bl, s_loc)
            if window:
                valid &= pos[None, :] > idx[:, None] - window
            vmask = valid[:, None, None, :]
        else:
            local_pos = jnp.clip(idx - start, 0, s_loc - 1)
            in_range = (idx >= start) & (idx < start + s_loc)

            def write(c, new):
                upd = jax.lax.dynamic_update_slice_in_dim(
                    c, new[:, None].astype(c.dtype), local_pos, axis=1
                )
                return jnp.where(in_range, upd, c)

            valid = pos <= idx
            if window:
                valid &= pos > idx - window
            vmask = valid[None, None, None, :]

        kc = write(kc, kn)
        vc = write(vc, vn)

        s = jnp.einsum(
            "bhrd,bshd->bhrs", q.astype(jnp.float32), kc.astype(jnp.float32)
        ) * (q.shape[-1] ** -0.5)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(vmask, s, NEG_INF)

        m_loc = jnp.max(s, axis=-1)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhrs,bshd->bhrd", p, vc.astype(jnp.float32))

        if saxes:
            m_g = jax.lax.pmax(m_loc, saxes)
            m_g = jnp.maximum(m_g, -1e30)
            corr = jnp.exp(m_loc - m_g)
            l_g = jax.lax.psum(l_loc * corr, saxes)
            o_g = jax.lax.psum(o_loc * corr[..., None], saxes)
        else:
            l_g, o_g = l_loc, o_loc
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)
        return out, kc, vc

    b = baxes or None
    s_sp = saxes or None
    in_specs = (
        P(b, None, None, None),          # q
        P(b, s_sp, None, None),          # k_cache
        P(b, s_sp, None, None),          # v_cache
        P(b, None, None),                # k_new
        P(b, None, None),                # v_new
        P(b) if vec_index else P(),      # cur_index (vector is per-slot)
    )
    out_specs = (
        P(b, None, None, None),
        P(b, s_sp, None, None),
        P(b, s_sp, None, None),
    )
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, k_new, v_new, cur_index)


# ---------------------------------------------------------------------------
# Paged KV cache: block-table plumbing + paged decode
# ---------------------------------------------------------------------------
#
# The pool layout is (num_blocks, block_size, Hk, D): logical position ``p``
# of a lane lives at physical row ``table[p // bs] * bs + p % bs`` of the
# flattened pool.  Physical block 0 is a write sink (serve/paged.py reserves
# it): unmapped table entries and invalid positions route writes there, so
# garbage never lands in a live block and the sink is never read (reads are
# masked to ``pos <= length``, and every readable position's block is
# mapped by construction).


def paged_gather(pool, tables):
    """Materialise lanes from the pool in logical position order.

    pool: (NB, bs, ...); tables: (B, nb) int32.  Returns (B, nb*bs, ...)
    — index ``p`` of a row is logical position ``p`` of that lane
    (garbage from the null block where unmapped; callers mask by length).
    """
    g = jnp.take(pool, tables, axis=0)                  # (B, nb, bs, ...)
    return g.reshape(tables.shape[0], -1, *pool.shape[2:])


def _physical_rows(table, positions, bs: int, nb: int):
    """Flat pool rows for logical ``positions`` under one table row; out-of
    -range positions clamp into the last block (callers only pass them for
    stale lanes whose table rows are nulled — the clamp lands in the sink)."""
    li = jnp.clip(positions // bs, 0, nb - 1)
    blk = jnp.take(table, li)
    off = jnp.clip(positions - li * bs, 0, bs - 1)
    return blk * bs + off


def paged_write_token(pool, tables, lengths, new):
    """Write one new token's K or V per lane at logical ``lengths[b]``.

    pool: (NB, bs, Hk, D); tables: (B, nb); new: (B, Hk, D).  Lanes whose
    block for that position is unmapped (free/stale lanes) write into the
    null sink.  Only the B written rows are touched — the paged analogue
    of ``decode_attention``'s per-row scatter.
    """
    NB, bs = pool.shape[:2]
    nb = tables.shape[1]
    B = tables.shape[0]
    li = jnp.clip(lengths // bs, 0, nb - 1)
    blk = jnp.take_along_axis(tables, li[:, None], axis=1)[:, 0]
    off = jnp.clip(lengths - li * bs, 0, bs - 1)
    flat = pool.reshape(NB * bs, *pool.shape[2:])
    flat = flat.at[blk * bs + off].set(new.astype(pool.dtype))
    return flat.reshape(pool.shape)


def paged_write_positions(pool, table, positions, new, valid=None):
    """Scatter a chunk of positions of ONE lane into the pool.

    pool: (NB, bs, Hk, D) or layer-stacked (Lf, NB, bs, Hk, D);
    table: (nb,) int32; positions: (P,); new matches pool's lead plus
    (P, Hk, D).  ``valid=False`` positions (prompt padding) divert to the
    null sink.
    """
    stacked = pool.ndim == 5
    NB, bs = (pool.shape[1], pool.shape[2]) if stacked else pool.shape[:2]
    rows = _physical_rows(table, positions, bs, table.shape[0])
    if valid is not None:
        rows = jnp.where(valid, rows, 0)
    if stacked:
        flat = pool.reshape(pool.shape[0], NB * bs, *pool.shape[3:])
        flat = flat.at[:, rows].set(new.astype(pool.dtype))
    else:
        flat = pool.reshape(NB * bs, *pool.shape[2:])
        flat = flat.at[rows].set(new.astype(pool.dtype))
    return flat.reshape(pool.shape)


def paged_decode_attention(
    q, k_pool, v_pool, k_new, v_new, lengths, tables, *,
    window: int = 0,
    softcap: float = 0.0,
    impl: str = "ref",
):
    """One decoding step against the paged (block-table) KV cache.

    q:             (B, Hk, rep, D) — current-token queries (RoPE applied)
    k_pool/v_pool: (NB, bs, Hk, D) — the shared block pool
    k_new/v_new:   (B, Hk, D) — written at logical position ``lengths[b]``
    lengths:       (B,) int32 — tokens already in each lane
    tables:        (B, nb) int32 — the lanes' block-table rows
    impl:          "ref" gathers lanes and runs the masked-softmax XLA
                   path (bitwise-identical to the slotted
                   ``decode_attention`` on equal inputs — the parity
                   anchor); "pallas" dispatches the block-walking kernel
                   (kernels/paged_attention) that never materialises the
                   gathered lanes.

    Returns (out (B, Hk, rep, D), k_pool', v_pool').
    """
    k_pool = paged_write_token(k_pool, tables, lengths, k_new)
    v_pool = paged_write_token(v_pool, tables, lengths, v_new)
    if impl == "pallas":
        from repro.kernels import paged_attention
        out = paged_attention(
            q, k_pool, v_pool, lengths, tables,
            window=window, softcap=softcap,
        )
    else:
        # the kernel's jnp oracle IS the production reference path, so the
        # kernel-vs-ref tests cover exactly what serves here
        from repro.kernels.paged_attention.ref import paged_attention_ref
        out = paged_attention_ref(
            q, k_pool, v_pool, lengths, tables,
            window=window, softcap=softcap,
        )
    return out, k_pool, v_pool
