"""Shared model machinery: sharding rules, initialisation, norms.

Models are pure-functional: parameters are pytrees of arrays, every model
exposes ``param_specs`` (abstract ShapeDtypeStructs + PartitionSpecs, used
by the dry-run without allocating), ``init``, ``loss_fn``, ``prefill`` and
``decode_step``.

Sharding is expressed through :class:`ShardRules`, which maps *logical*
dimension names to mesh axes:

  ``dp``    — batch (data parallel; the paper's scatter axis)
  ``tp``    — tensor parallel (heads / ffn hidden / vocab / experts)
  ``fsdp``  — parameter & optimizer-state sharding (ZeRO; ``None`` in the
              paper-faithful replicated mode)
  ``sp``    — sequence-parallel residual stream between blocks
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardRules:
    """Logical-axis -> mesh-axis mapping.

    ``faithful()`` reproduces the paper: parameters replicated over the
    data-parallel workers (no fsdp), gradients combined by an explicit
    all-reduce.  The default is the beyond-paper ZeRO/SP configuration.

    Carries the mesh so constraints lower to explicit ``NamedSharding``s
    (robust outside a ``with mesh:`` context, e.g. AOT dry-run lowering).
    """

    dp: tuple[str, ...] = ("pod", "data")
    tp: str | None = "model"
    fsdp: str | None = "data"
    sp: bool = True
    mesh: Any = None

    def axis(self, logical: str | None):
        if logical is None:
            return None
        if logical == "dp":
            return self.dp
        if logical == "tp":
            return self.tp
        if logical == "fsdp":
            return self.fsdp
        if logical == "sp":
            return self.tp if self.sp else None
        raise ValueError(f"unknown logical axis {logical!r}")

    def pspec(self, *logical: str | None) -> P:
        return P(*[self.axis(l) for l in logical])

    def axis_size(self, axes) -> int:
        if axes is None or self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    @classmethod
    def faithful(cls, dp=("pod", "data"), tp="model", mesh=None) -> "ShardRules":
        return cls(dp=dp, tp=tp, fsdp=None, sp=False, mesh=mesh)

    @classmethod
    def for_mesh(cls, mesh, *, faithful: bool = False) -> "ShardRules":
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names)
        tp = "model" if "model" in names else None
        if faithful:
            return cls.faithful(dp=dp, tp=tp, mesh=mesh)
        return cls(dp=dp, tp=tp, fsdp="data" if "data" in names else None,
                   sp=tp is not None, mesh=mesh)


_MANUAL_MODE = False  # inside a per-worker shard_map program: constraints off


class manual_mode:
    """Trace-time switch disabling sharding constraints.

    The flat-gradient train step runs the model as an explicit per-worker
    program inside ``shard_map``; there the mesh axes are manual and
    ``with_sharding_constraint`` over them is meaningless (and rejected by
    some JAX versions).  Model code stays unchanged — ``constrain``/
    ``constrain_spec``/``wuse`` become identity while a ``manual_mode()``
    block is active during tracing."""

    def __enter__(self):
        global _MANUAL_MODE
        self._prev = _MANUAL_MODE
        _MANUAL_MODE = True
        return self

    def __exit__(self, *exc):
        global _MANUAL_MODE
        _MANUAL_MODE = self._prev
        return False


def constrain(x, rules: ShardRules, *logical: str | None):
    """``with_sharding_constraint`` by logical axes.

    Dims that don't divide their mesh axes fall back to replicated on that
    dim (deterministic — no silent exception swallowing)."""
    if _MANUAL_MODE:
        return x
    resolved = []
    for i, l in enumerate(logical):
        axes = rules.axis(l)
        if axes is not None and x.shape[i] % max(rules.axis_size(axes), 1) != 0:
            axes = None
        resolved.append(axes)
    spec = P(*resolved)
    if rules.mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_spec(x, mesh, spec: P):
    """with_sharding_constraint with an explicit PartitionSpec + mesh."""
    if _MANUAL_MODE:
        return x
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def wuse(w, rules: ShardRules, *logical: str | None, dtype=None):
    """Cast a stored parameter to the compute dtype and re-pin its sharding.

    Without the re-pin, SPMD may place the FSDP all-gather on the *stored*
    (fp32) tensor and cast afterwards — doubling gather wire bytes.  Pinning
    the casted copy to the same logical sharding forces collectives to move
    the compute-dtype bytes."""
    if dtype is not None and w.dtype != dtype:
        # the barrier stops the backend from eliding/hoisting the cast above
        # the FSDP all-gather (XLA:CPU legalizes bf16 dots to f32 and would
        # otherwise gather fp32 weights — 2x wire)
        w = compat.optimization_barrier(w.astype(dtype))
    return constrain(w, rules, *logical)


# ---------------------------------------------------------------------------
# Parameter declaration: shapes + shardings declared together
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init_scale: float | None = None   # None -> fan-in scaled normal

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def pspec(self, rules: ShardRules) -> P:
        return rules.pspec(*self.logical)


def spec_tree_to_sds(tree):
    return jax.tree.map(
        lambda s: s.sds(), tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def spec_tree_to_pspecs(tree, rules: ShardRules):
    return jax.tree.map(
        lambda s: s.pspec(rules), tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def init_param(key, spec: ParamSpec):
    if spec.init_scale == 0.0:
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init_scale is not None:
        return (spec.init_scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    scale = 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)


def init_tree(key, tree):
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [init_param(k, s) for k, s in zip(keys, leaves)]
    )


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def decode_positions(cur_index, batch: int):
    """``(B, 1)`` int32 RoPE position row per sequence for a decode step.

    ``cur_index`` is either a scalar (classic batched decode: every
    sequence sits at the same position) or a ``(B,)`` vector (the serve
    engine's slotted cache: each slot is at its own length).  Both
    broadcast to one position column per row.
    """
    cur = jnp.asarray(cur_index, jnp.int32)
    return jnp.broadcast_to(cur, (batch,))[:, None]


def remat_wrap(body, remat):
    """Apply a rematerialisation policy to a scan body.

    remat: False | True (save nothing) | "dots" (save matmul outputs —
    trades activation memory for skipping recompute collectives)."""
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if remat:
        return jax.checkpoint(body)
    return body


def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def swiglu(x, w_gate, w_up, w_down, rules: ShardRules | None = None):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token-level cross entropy; logits (..., V) fp32-promoted.

    The gold-logit pick is an iota-compare-select reduction (not
    ``take_along_axis``) so it partitions cleanly when V is sharded over
    the tp axis (XLA fuses it; the (.., V) one-hot is never materialised).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(iota == labels[..., None], logits, 0.0), axis=-1
    )
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
