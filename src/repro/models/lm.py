"""Decoder-only LM assembly: dense (llama/deepseek/stablelm/smollm),
gemma2 (alternating local/global attention + softcaps + post-norms),
qwen3-MoE (expert-parallel FFN), and InternVL-style VLM (stubbed vision
frontend projected into the sequence).

Layers are stacked on a leading axis and executed with ``lax.scan`` (pairs
of (local, global) layers for gemma2), which keeps HLO size independent of
depth — essential for 95-layer models partitioned over 512 devices.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from .attention import (
    DecodeSharding,
    chunked_attention,
    decode_attention,
    paged_decode_attention,
    paged_gather,
    paged_write_positions,
    rope,
)
from .common import (
    ParamSpec,
    ShardRules,
    constrain,
    cross_entropy_loss,
    decode_positions,
    init_tree,
    rms_norm,
    softcap,
    wuse,
)
from .moe import moe_ffn

# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


def _leading(cfg: ArchConfig) -> tuple[int, ...]:
    if cfg.alt_local_global:
        assert cfg.n_layers % 2 == 0, "alternating archs need an even layer count"
        return (cfg.n_layers // 2, 2)
    return (cfg.n_layers,)


def _lead_logical(cfg: ArchConfig) -> tuple[None, ...]:
    return (None,) * len(_leading(cfg))


def block_specs(cfg: ArchConfig) -> dict:
    lead, ll = _leading(cfg), _lead_logical(cfg)
    D, dh, H, Hk = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv
    dt = jnp.dtype(cfg.param_dtype)
    s: dict[str, ParamSpec] = {
        "ln1": ParamSpec(lead + (D,), ll + (None,), dt, init_scale=0.0),
        "ln2": ParamSpec(lead + (D,), ll + (None,), dt, init_scale=0.0),
        "wq": ParamSpec(lead + (D, H * dh), ll + ("fsdp", "tp"), dt),
        "wk": ParamSpec(lead + (D, Hk * dh), ll + ("fsdp", "tp"), dt),
        "wv": ParamSpec(lead + (D, Hk * dh), ll + ("fsdp", "tp"), dt),
        "wo": ParamSpec(lead + (H * dh, D), ll + ("tp", "fsdp"), dt),
    }
    if cfg.qk_norm:
        s["qnorm"] = ParamSpec(lead + (dh,), ll + (None,), dt, init_scale=0.0)
        s["knorm"] = ParamSpec(lead + (dh,), ll + (None,), dt, init_scale=0.0)
    if cfg.alt_local_global:  # gemma2 post-norms
        s["ln1b"] = ParamSpec(lead + (D,), ll + (None,), dt, init_scale=0.0)
        s["ln2b"] = ParamSpec(lead + (D,), ll + (None,), dt, init_scale=0.0)
    if cfg.moe.num_experts:
        E, F = cfg.moe.num_experts, cfg.moe.d_expert
        s["router"] = ParamSpec(lead + (D, E), ll + (None, None), dt)
        s["wg_e"] = ParamSpec(lead + (E, D, F), ll + ("tp", "fsdp", None), dt)
        s["wu_e"] = ParamSpec(lead + (E, D, F), ll + ("tp", "fsdp", None), dt)
        s["wd_e"] = ParamSpec(lead + (E, F, D), ll + ("tp", None, "fsdp"), dt)
    else:
        F = cfg.d_ff
        s["wg"] = ParamSpec(lead + (D, F), ll + ("fsdp", "tp"), dt)
        s["wu"] = ParamSpec(lead + (D, F), ll + ("fsdp", "tp"), dt)
        s["wd"] = ParamSpec(lead + (F, D), ll + ("tp", "fsdp"), dt)
    return s


def param_specs(cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    s = {
        "embed": ParamSpec((cfg.vocab, D), ("tp", "fsdp"), dt),
        "ln_f": ParamSpec((D,), (None,), dt, init_scale=0.0),
        "blocks": block_specs(cfg),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((D, cfg.vocab), ("fsdp", "tp"), dt)
    if cfg.family == "vlm":
        s["img_proj"] = ParamSpec((cfg.frontend_dim, D), (None, "fsdp"), dt)
    return s


def init(cfg: ArchConfig, key) -> dict:
    return init_tree(key, param_specs(cfg))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _gate(cfg: ArchConfig, g):
    return jax.nn.gelu(g) if cfg.gate_act == "gelu" else jax.nn.silu(g)


def _q_scale(cfg: ArchConfig) -> float:
    # chunked_attention applies dh**-0.5; fold any override into q.
    if cfg.query_scale:
        return cfg.query_scale * (cfg.head_dim ** 0.5)
    return 1.0


def _tp_size(mesh: Mesh, rules: ShardRules) -> int:
    return mesh.shape[rules.tp] if rules.tp and rules.tp in mesh.axis_names else 1


def _attn_proj(cfg, mesh, rules, h, bp, positions):
    B, S, _ = h.shape
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    cdt = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dk->bsk", h, wuse(bp["wq"], rules, "fsdp", "tp", dtype=cdt)).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,dk->bsk", h, wuse(bp["wk"], rules, "fsdp", "tp", dtype=cdt)).reshape(B, S, Hk, dh)
    v = jnp.einsum("bsd,dk->bsk", h, wuse(bp["wv"], rules, "fsdp", "tp", dtype=cdt)).reshape(B, S, Hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, bp["qnorm"], cfg.norm_eps)
        k = rms_norm(k, bp["knorm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta) * _q_scale(cfg)
    k = rope(k, positions, cfg.rope_theta)
    tp = _tp_size(mesh, rules)
    q = constrain(q, rules, "dp", None, "tp" if H % tp == 0 else None, None)
    k = constrain(k, rules, "dp", None, "tp" if Hk % tp == 0 else None, None)
    return q, k, v


def _ffn(cfg, mesh, rules, x, bp):
    """Returns (ffn_out, aux)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.moe.num_experts:
        return moe_ffn(
            x, bp["router"], bp["wg_e"], bp["wu_e"], bp["wd_e"],
            cfg=cfg, mesh=mesh, rules=rules,
        )
    g = jnp.einsum("bsd,df->bsf", x, wuse(bp["wg"], rules, "fsdp", "tp", dtype=cdt))
    u = jnp.einsum("bsd,df->bsf", x, wuse(bp["wu"], rules, "fsdp", "tp", dtype=cdt))
    h = _gate(cfg, g) * u
    h = constrain(h, rules, "dp", None, "tp")
    out = jnp.einsum("bsf,fd->bsd", h, wuse(bp["wd"], rules, "tp", "fsdp", dtype=cdt))
    out = constrain(out, rules, "dp", "sp", None)   # psum -> reduce-scatter
    return out, {"lb_loss": jnp.float32(0.0), "drop_frac": jnp.float32(0.0)}


def _block_fwd(cfg, mesh, rules, x, bp, positions, *, window: int,
               collect_kv: bool, attn_fn=None):
    """One transformer block, training/prefill path.

    ``attn_fn(q, k, v, window) -> (attn, extra)`` overrides the attention
    step (the chunked-prefill path writes K/V through a block table and
    attends against the lane's cache); everything around it — projections,
    norms, residuals, FFN — is shared so the paths stay numerically
    identical.  Returns (x, aux, kv): kv is (k, v) when ``collect_kv``,
    else ``attn_fn``'s extra (None on the default path).
    """
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    h = constrain(h, rules, "dp", "sp", None)
    q, k, v = _attn_proj(cfg, mesh, rules, h, bp, positions)
    extra = None
    if attn_fn is not None:
        attn, extra = attn_fn(q, k, v, window)
    elif cfg.attn_impl == "pallas":
        # TPU hot-spot path: fused flash kernel with dynamic block skipping
        # (validated against chunked_attention in tests/test_kernels.py)
        from repro.kernels import flash_attention
        attn = flash_attention(
            q, k, v, causal=True, window=window, softcap=cfg.attn_softcap,
        )
    else:
        attn = chunked_attention(
            q, k, v,
            causal=True,
            window=window,
            softcap=cfg.attn_softcap,
            q_chunk=min(256, q.shape[1]),
            kv_chunk=min(256, k.shape[1]),
        )
    B, S = x.shape[:2]
    cdt = jnp.dtype(cfg.compute_dtype)
    o = jnp.einsum(
        "bsk,kd->bsd", attn.reshape(B, S, -1), wuse(bp["wo"], rules, "tp", "fsdp", dtype=cdt)
    )
    # pin the psum output BEFORE the residual add so the TP partial sum
    # lowers to reduce-scatter (all-reduce + slice after the add costs 2x)
    o = constrain(o, rules, "dp", "sp", None)
    if cfg.alt_local_global:
        o = rms_norm(o, bp["ln1b"], cfg.norm_eps)
    x = constrain(x + o, rules, "dp", "sp", None)
    h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
    h2 = constrain(h2, rules, "dp", "sp", None)
    ffn, aux = _ffn(cfg, mesh, rules, h2, bp)
    if cfg.alt_local_global:
        ffn = rms_norm(ffn, bp["ln2b"], cfg.norm_eps)
    x = constrain(x + ffn, rules, "dp", "sp", None)
    kv = (k, v) if collect_kv else extra
    return x, aux, kv


def _block_decode(cfg, mesh, rules, x, bp, kc, vc, cur_index, *, window: int,
                  dec_sharding: DecodeSharding | None, attn_fn=None):
    """One block, single-token decode. x: (B, D). Returns (x, kc, vc).

    ``attn_fn(q, kc, vc, k_new, v_new, window)`` overrides the cache-write
    + attention step (the paged path); the default is the slotted
    ``decode_attention`` under ``dec_sharding``.  Everything around the
    attention call is shared so the layouts stay numerically identical.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    B = x.shape[0]
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bd,dk->bk", h, bp["wq"].astype(cdt)).reshape(B, H, dh)
    k = jnp.einsum("bd,dk->bk", h, bp["wk"].astype(cdt)).reshape(B, Hk, dh)
    v = jnp.einsum("bd,dk->bk", h, bp["wv"].astype(cdt)).reshape(B, Hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, bp["qnorm"], cfg.norm_eps)
        k = rms_norm(k, bp["knorm"], cfg.norm_eps)
    pos = decode_positions(cur_index, B)
    q = rope(q[:, None], pos, cfg.rope_theta)[:, 0] * _q_scale(cfg)
    k = rope(k[:, None], pos, cfg.rope_theta)[:, 0]
    q = q.reshape(B, Hk, H // Hk, dh)
    if attn_fn is None:
        attn, kc, vc = decode_attention(
            q, kc, vc, k, v, cur_index,
            sharding=dec_sharding, window=window, softcap=cfg.attn_softcap,
        )
    else:
        attn, kc, vc = attn_fn(q, kc, vc, k, v, window)
    o = jnp.einsum("bk,kd->bd", attn.reshape(B, H * dh), bp["wo"].astype(cdt))
    if cfg.alt_local_global:
        o = rms_norm(o, bp["ln1b"], cfg.norm_eps)
    x = x + o
    h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
    ffn, _ = _ffn(cfg, mesh, rules, h2[:, None], bp)
    ffn = ffn[:, 0]
    if cfg.alt_local_global:
        ffn = rms_norm(ffn, bp["ln2b"], cfg.norm_eps)
    return x + ffn, kc, vc


def _sub(tree, i):
    return jax.tree.map(lambda p: p[i], tree)


def _windows(cfg: ArchConfig) -> tuple[int, ...]:
    """Window per sub-block within a scan step."""
    if cfg.alt_local_global:
        return (cfg.window, 0)       # (local, global)
    return (cfg.window,)             # 0 => full causal


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def embed_tokens(cfg, rules, params, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(wuse(params["embed"], rules, "tp", "fsdp", dtype=cdt), tokens, axis=0)
    if cfg.alt_local_global:   # gemma scales embeddings
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    return x


def unembed(cfg, rules, params, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = wuse(params["embed"], rules, "tp", "fsdp", dtype=cdt).T
    else:
        w = wuse(params["unembed"], rules, "fsdp", "tp", dtype=cdt)
    logits = jnp.einsum("...d,dv->...v", x, w)
    logits = constrain(logits, rules, "dp", None, "tp") if logits.ndim == 3 \
        else constrain(logits, rules, "dp", "tp")
    if cfg.logit_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits


def forward(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params, tokens,
            img_embeds=None, *, remat: bool = True, collect_kv: bool = False):
    """Returns (hidden (B,S,D), aux, kv_stack or None)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(cfg, rules, params, tokens)
    if cfg.family == "vlm":
        img = jnp.einsum(
            "bnf,fd->bnd", img_embeds.astype(cdt), params["img_proj"].astype(cdt)
        )
        x = jnp.concatenate([img, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, rules, "dp", "sp", None)

    windows = _windows(cfg)

    def body(carry, bp):
        x, lb, dr = carry
        kvs = []
        for i, w in enumerate(windows):
            sub_bp = _sub(bp, i) if len(windows) > 1 else bp
            x, aux, kv = _block_fwd(
                cfg, mesh, rules, x, sub_bp, positions,
                window=w, collect_kv=collect_kv,
            )
            lb, dr = lb + aux["lb_loss"], jnp.maximum(dr, aux["drop_frac"])
            kvs.append(kv)
        if collect_kv:
            ys = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs) if len(kvs) > 1 else kvs[0]
        else:
            ys = None
        return (x, lb, dr), ys

    from .common import remat_wrap
    body = remat_wrap(body, remat)
    (x, lb, dr), kv_stack = jax.lax.scan(
        body, (x, jnp.float32(0.0), jnp.float32(0.0)), params["blocks"]
    )
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, {"lb_loss": lb, "drop_frac": dr}, kv_stack


def loss_fn(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params, batch,
            *, remat: bool = True):
    tokens = batch["tokens"]                    # (B, S_text + 1)
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    img = batch.get("patch_embeds")
    hidden, aux, _ = forward(cfg, mesh, rules, params, inp, img, remat=remat)
    if cfg.family == "vlm":
        n = cfg.frontend_tokens
        hidden = hidden[:, n - 1 : n - 1 + labels.shape[1]]
    logits = unembed(cfg, rules, params, hidden)
    loss = cross_entropy_loss(logits, labels)
    total = loss + 1e-2 * aux["lb_loss"] / max(cfg.n_layers, 1)
    return total, {"ce_loss": loss, **aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def make_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """Abstract KV cache (lead..., B, S, Hk, dh) as ShapeDtypeStructs."""
    lead = _leading(cfg)
    shape = lead + (batch, max_len, cfg.n_kv, cfg.head_dim)
    c = jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.compute_dtype))
    return {"k": c, "v": c}


def lane_leaf_axes(cfg: ArchConfig) -> dict:
    """{cache leaf name -> lane axis} for the *slotted* cache — everything
    one lane owns, used by the host tier to spill/restore a whole lane as
    one copy.  For the lm families both leaves put the lane right after
    the leading (layer[, k/v]) axes."""
    lead = len(_leading(cfg))
    return {"k": lead, "v": lead}


def cache_pspec(cfg: ArchConfig, dec: DecodeSharding):
    lead = (None,) * len(_leading(cfg))
    from jax.sharding import PartitionSpec as P
    spec = P(*lead, dec.batch_axes or None, dec.seq_axes or None, None, None)
    return {"k": spec, "v": spec}


def make_paged_cache_specs(cfg: ArchConfig, num_blocks: int, block_size: int):
    """Abstract paged KV pool (lead..., NB, bs, Hk, dh)."""
    lead = _leading(cfg)
    shape = lead + (num_blocks, block_size, cfg.n_kv, cfg.head_dim)
    c = jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.compute_dtype))
    return {"k": c, "v": c}


def paged_cache_pspec(cfg: ArchConfig, mesh: Mesh, num_blocks: int = 0):
    """Pool sharding: blocks over the data axes (so per-device reservation
    shrinks with DP size — matching how the slotted cache batch-shards its
    lanes; table gathers become collectives, a bandwidth-for-HBM trade)
    and KV heads over the tensor axis, each when divisible."""
    lead = (None,) * len(_leading(cfg))
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ndp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    blk = dp if (dp and num_blocks and num_blocks % ndp == 0) else None
    tp = "model" if (
        "model" in mesh.axis_names and cfg.n_kv % mesh.shape["model"] == 0
    ) else None
    spec = P(*lead, blk, None, tp, None)
    return {"k": spec, "v": spec}


def copy_paged_block(cfg: ArchConfig, cache, src, dst):
    """Copy physical pool block ``src`` to ``dst`` in every cache leaf —
    the copy-on-write step of prefix caching: when a new request's prompt
    fully covers a shared block but must rewrite its tail position (the
    sampling position is always recomputed), the engine clones the block
    and hands the lane the private copy.

    Leaves are (L[,2], NB, bs, Hk, dh); the block axis is ``ndim - 4``.
    ``src``/``dst`` are traced scalars so ONE executable serves every
    copy.
    """
    def cp(c):
        axis = c.ndim - 4
        row = jax.lax.dynamic_index_in_dim(c, src, axis, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(c, row, dst, axis)

    return {name: cp(c) for name, c in cache.items()}


def prefill(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params, tokens,
            img_embeds=None, *, max_len: int | None = None):
    """Returns (cache {k,v}, last-token logits (B, V))."""
    hidden, _, kv = forward(
        cfg, mesh, rules, params, tokens, img_embeds,
        remat=False, collect_kv=True,
    )
    k, v = kv                                   # (L[,2], B, S, Hk, dh)
    dec = DecodeSharding.choose(mesh, tokens.shape[0])

    def pad(c):
        if max_len and max_len > c.shape[-3]:
            pad_width = [(0, 0)] * c.ndim
            pad_width[-3] = (0, max_len - c.shape[-3])
            c = jnp.pad(c, pad_width)
        return c

    cache = {"k": pad(k), "v": pad(v)}
    specs = cache_pspec(cfg, dec)
    from .common import constrain_spec
    cache = {
        name: constrain_spec(c, mesh, specs[name]) for name, c in cache.items()
    }
    logits = unembed(cfg, rules, params, hidden[:, -1])
    return cache, logits


def prefill_slot(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params,
                 cache, tokens, slot, plen):
    """Prefill ONE prompt into lane ``slot`` of a slotted KV cache.

    tokens: (1, S_bucket) int32 — the prompt, right-padded to its length
    bucket; ``plen`` (traced scalar) is the real prompt length and ``slot``
    (traced scalar) the lane index.  Causality makes the padding inert:
    positions < plen never attend the padded tail, and the tail's garbage
    KV is overwritten by decode steps before the sequence reaches it.

    Returns (cache', logits (1, V) at position plen-1).
    """
    hidden, _, kv = forward(
        cfg, mesh, rules, params, tokens, None, remat=False, collect_kv=True,
    )
    k, v = kv                                   # (L[,2], 1, S_bucket, Hk, dh)
    lead = len(_leading(cfg))

    def write(c, new):
        start = (0,) * lead + (slot, 0, 0, 0)
        return jax.lax.dynamic_update_slice(c, new.astype(c.dtype), start)

    cache = {"k": write(cache["k"], k), "v": write(cache["v"], v)}
    last = jax.lax.dynamic_index_in_dim(hidden, plen - 1, 1, keepdims=False)
    return cache, unembed(cfg, rules, params, last)


def prefill_slot_paged(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params,
                       cache, tokens, table_row, plen):
    """Prefill the FIRST chunk (positions [0, C)) of one lane into the
    paged pool through its block table.

    Runs the same ``forward`` as :func:`prefill_slot` — activations are
    bitwise-identical, which anchors slotted-vs-paged greedy parity — but
    the collected KV scatters into pool blocks instead of a lane slice.
    tokens: (1, C) right-padded; positions ``>= plen`` divert to the null
    sink block.  Returns (cache', logits (1, V) at ``min(plen, C) - 1``).
    """
    hidden, _, kv = forward(
        cfg, mesh, rules, params, tokens, None, remat=False, collect_kv=True,
    )
    k, v = kv                                   # (L[,2], 1, C, Hk, dh)
    C = tokens.shape[1]
    pos = jnp.arange(C)
    valid = pos < plen

    def write(pool, new):
        flat_pool = pool.reshape((-1,) + pool.shape[-4:])
        new = new.reshape(-1, C, cfg.n_kv, cfg.head_dim)
        out = paged_write_positions(flat_pool, table_row, pos, new, valid)
        return out.reshape(pool.shape)

    cache = {"k": write(cache["k"], k), "v": write(cache["v"], v)}
    last = jax.lax.dynamic_index_in_dim(
        hidden, jnp.clip(plen - 1, 0, C - 1), 1, keepdims=False)
    return cache, unembed(cfg, rules, params, last)


def _block_chunk(cfg, mesh, rules, x, bp, kp, vp, table_row, start, plen, *,
                 window: int):
    """One transformer block of a chunked-prefill continuation.

    x: (1, C, D) hidden for prompt positions [start, start+C); kp/vp:
    block pools (NB, bs, Hk, dh).  Rides ``_block_fwd`` with an attention
    override: write the chunk's K/V through the table, then attend the
    chunk's queries against the lane's gathered KV (previous chunks + the
    chunk itself; the stale tail beyond ``start+C`` is causally masked,
    pad rows never feed valid rows).  Returns (x, kp, vp).
    """
    C = x.shape[1]
    pos = start + jnp.arange(C)

    def attn_fn(q, k, v, w):
        valid = pos < plen
        kp2 = paged_write_positions(kp, table_row, pos, k[0], valid)
        vp2 = paged_write_positions(vp, table_row, pos, v[0], valid)
        kl = paged_gather(kp2, table_row[None])   # (1, S_mapped_view, Hk, dh)
        vl = paged_gather(vp2, table_row[None])
        attn = chunked_attention(
            q, kl, vl,
            causal=True,
            window=w,
            softcap=cfg.attn_softcap,
            q_chunk=min(256, C),
            kv_chunk=min(256, kl.shape[1]),
            q_offset=start,
        )
        return attn, (kp2, vp2)

    x, _, (kp, vp) = _block_fwd(
        cfg, mesh, rules, x, bp, pos[None],
        window=window, collect_kv=False, attn_fn=attn_fn,
    )
    return x, kp, vp


def prefill_chunk_paged(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                        params, cache, tokens, table_row, start, plen):
    """Continue a chunked prefill: prompt positions [start, start+C)
    against the lane's existing paged KV (``start > 0``; the first chunk
    goes through :func:`prefill_slot_paged`).

    tokens: (1, C) — the chunk, right-padded on the last chunk; ``start``
    and ``plen`` are traced scalars so ONE executable per chunk size
    serves every continuation.  Returns (cache', logits (1, V) at prompt
    position ``min(plen, start+C) - 1`` — meaningful on the last chunk).
    """
    x = embed_tokens(cfg, rules, params, tokens)          # (1, C, D)
    x = constrain(x, rules, "dp", "sp", None)
    C = tokens.shape[1]
    windows = _windows(cfg)

    def body(i, carry):
        x, kp_all, vp_all = carry
        idx = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
        bp = jax.tree.map(idx, params["blocks"])
        kp, vp = idx(kp_all), idx(vp_all)
        if len(windows) > 1:
            kps, vps = [], []
            for j, w in enumerate(windows):
                x, kpj, vpj = _block_chunk(
                    cfg, mesh, rules, x, _sub(bp, j), kp[j], vp[j],
                    table_row, start, plen, window=w,
                )
                kps.append(kpj); vps.append(vpj)
            kp, vp = jnp.stack(kps), jnp.stack(vps)
        else:
            x, kp, vp = _block_chunk(
                cfg, mesh, rules, x, bp, kp, vp, table_row, start, plen,
                window=windows[0],
            )
        upd = lambda a, s: jax.lax.dynamic_update_index_in_dim(a, s, i, 0)
        return x, upd(kp_all, kp), upd(vp_all, vp)

    L = jax.tree.leaves(params["blocks"])[0].shape[0]
    x, k_new, v_new = jax.lax.fori_loop(
        0, L, body, (x, cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    last = jax.lax.dynamic_index_in_dim(
        x, jnp.clip(plen - 1 - start, 0, C - 1), 1, keepdims=False)
    return {"k": k_new, "v": v_new}, unembed(cfg, rules, params, last)


def _decode_walk(cfg, mesh, rules, params, cache, x, cur_index, dec, attn_fn):
    """Shared per-layer decode walk for the slotted and paged layouts.

    fori_loop with in-place dynamic updates on the carried cache: the
    stacked KV cache lives in ONE buffer (a scan's xs+ys would
    double-buffer it — 2x HBM for the dominant decode tensor).  The
    leading layer axis is unsharded, so the per-layer slice/update is
    local (no collectives).
    """
    windows = _windows(cfg)

    def body(i, carry):
        x, kc_all, vc_all = carry
        idx = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
        bp = jax.tree.map(idx, params["blocks"])
        kc, vc = idx(kc_all), idx(vc_all)
        if len(windows) > 1:
            kcs, vcs = [], []
            for j, w in enumerate(windows):
                x, kcj, vcj = _block_decode(
                    cfg, mesh, rules, x, _sub(bp, j), kc[j], vc[j], cur_index,
                    window=w, dec_sharding=dec, attn_fn=attn_fn,
                )
                kcs.append(kcj); vcs.append(vcj)
            kc, vc = jnp.stack(kcs), jnp.stack(vcs)
        else:
            x, kc, vc = _block_decode(
                cfg, mesh, rules, x, bp, kc, vc, cur_index,
                window=windows[0], dec_sharding=dec, attn_fn=attn_fn,
            )
        upd = lambda a, s: jax.lax.dynamic_update_index_in_dim(a, s, i, 0)
        return x, upd(kc_all, kc), upd(vc_all, vc)

    L = jax.tree.leaves(params["blocks"])[0].shape[0]
    x, k_new, v_new = jax.lax.fori_loop(
        0, L, body, (x, cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(cfg, rules, params, x)
    return logits, {"k": k_new, "v": v_new}


def decode_step(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params, cache,
                tokens, cur_index):
    """tokens: (B,) int32; cur_index: tokens already in cache — a scalar
    (aligned batch) or a (B,) vector (slotted cache, per-lane positions).

    Returns (logits (B, V), new cache).
    """
    x = embed_tokens(cfg, rules, params, tokens[:, None])[:, 0]
    dec = DecodeSharding.choose(mesh, tokens.shape[0])
    return _decode_walk(cfg, mesh, rules, params, cache, x, cur_index, dec, None)


def decode_step_paged(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, params,
                      cache, tokens, lengths, tables, *, impl: str = "ref"):
    """Paged decode: cache leaves are block pools (L[,2], NB, bs, Hk, dh);
    ``tables`` (B, nb) maps each lane's logical blocks to pool rows and
    ``lengths`` (B,) is both the RoPE position and the write position of
    the new token.  ``impl`` picks the attention backend ("ref" jnp
    gather / "pallas" block-walking kernel).

    Returns (logits (B, V), new cache).
    """
    x = embed_tokens(cfg, rules, params, tokens[:, None])[:, 0]

    def attn_fn(q, kc, vc, k_new, v_new, window):
        return paged_decode_attention(
            q, kc, vc, k_new, v_new, lengths, tables,
            window=window, softcap=cfg.attn_softcap, impl=impl,
        )

    return _decode_walk(
        cfg, mesh, rules, params, cache, x, lengths, None, attn_fn)
