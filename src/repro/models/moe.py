"""Expert-parallel MoE FFN (Qwen3-style: 128 experts, top-8, softmax-gated).

Layout: experts are sharded over the ``model`` (tp) mesh axis; tokens of a
data-parallel column are sequence-sharded over the same axis between blocks
(sequence parallelism).  The layer:

  1. all-gathers the column's tokens over ``model`` (each rank sees the
     full column),
  2. routes locally (top-k), computes capacity slots with a sort-based
     position-in-expert (no (T,E,C) one-hot — that tensor is intractable
     at production sizes),
  3. gathers tokens into a per-local-expert (E_loc, C, D) buffer, runs the
     expert FFNs as batched matmuls (MXU-shaped),
  4. scatter-adds weighted outputs back to token slots and
     reduce-scatters the result over ``model``, restoring the
     sequence-parallel layout.

The collective pattern (all-gather + reduce-scatter over tp) matches what
tensor parallelism would pay for a dense FFN of the same width, so expert
parallelism here adds no extra collective classes — this is one of the
beyond-paper design choices recorded in DESIGN.md.

FSDP (``rules.fsdp``): expert weights arrive sharded on d_model and are
all-gathered per layer inside the shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from .common import ShardRules


def expert_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    moe = cfg.moe
    c = int(np.ceil(n_tokens * moe.top_k / moe.num_experts * moe.capacity_factor))
    c = max(c, min(n_tokens * moe.top_k, 8))   # decode-sized floors
    return int(np.ceil(c / 8) * 8)             # lane-aligned


def moe_ffn(
    x, router_w, w_gate, w_up, w_down, *,
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardRules,
):
    """x: (B, S, D) global. Returns (out (B, S, D), aux metrics dict)."""
    E = cfg.moe.num_experts
    K = cfg.moe.top_k
    D = cfg.d_model
    tp = rules.tp
    tp_size = mesh.shape[tp] if tp else 1
    dp = tuple(a for a in rules.dp if a in mesh.axis_names)

    B, S, _ = x.shape
    seq_sharded = tp is not None and S % tp_size == 0 and S >= tp_size
    ndp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    n_tokens_col = max(B // max(ndp, 1), 1) * S
    C = expert_capacity(n_tokens_col, cfg)
    E_loc = E // tp_size if tp else E

    fsdp = rules.fsdp if rules.fsdp and rules.fsdp in mesh.axis_names else None

    def shard_fn(x_loc, rw, wg, wu, wd):
        # x_loc: (B_l, S_l, D); expert weights local (E_loc, D[/fsdp], F)
        if fsdp:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        if seq_sharded and tp:
            x_col = jax.lax.all_gather(x_loc, tp, axis=1, tiled=True)  # (B_l, S, D)
        else:
            x_col = x_loc
        Bl = x_col.shape[0]
        T = Bl * x_col.shape[1]
        xt = x_col.reshape(T, D)

        # --- routing (computed redundantly on every tp rank; negligible) ---
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), rw.astype(jnp.float32))
        topk_w, topk_i = jax.lax.top_k(logits, K)          # (T, K)
        topk_w = jax.nn.softmax(topk_w, axis=-1)           # Qwen3 renormalises

        flat_e = topk_i.reshape(-1)                        # (T*K,)
        flat_w = topk_w.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)

        # --- sort-based position-in-expert (static shapes, O(TK log TK)) ---
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        ranks_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
        pos = jnp.zeros_like(flat_e).at[order].set(ranks_sorted)

        # --- local-expert slot assignment ---
        e_off = (jax.lax.axis_index(tp) * E_loc) if tp else 0
        e_loc = flat_e - e_off
        keep = (pos < C) & (e_loc >= 0) & (e_loc < E_loc)
        e_write = jnp.where(keep, e_loc, E_loc)            # OOB row -> dropped
        pos_c = jnp.clip(pos, 0, C - 1)

        idx_buf = jnp.full((E_loc + 1, C), T, jnp.int32)   # sentinel T -> zero row
        idx_buf = idx_buf.at[e_write, pos_c].set(flat_t, mode="drop")
        w_buf = jnp.zeros((E_loc + 1, C), jnp.float32)
        w_buf = w_buf.at[e_write, pos_c].set(flat_w, mode="drop")
        idx_buf, w_buf = idx_buf[:E_loc], w_buf[:E_loc]

        # --- expert compute: (E_loc, C, D) batched matmuls ---
        x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        xs = x_pad[idx_buf]                                # (E_loc, C, D)
        g = jnp.einsum("ecd,edf->ecf", xs, wg.astype(xs.dtype))
        u = jnp.einsum("ecd,edf->ecf", xs, wu.astype(xs.dtype))
        h = jax.nn.silu(g) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd.astype(xs.dtype))
        y = y * w_buf[..., None].astype(y.dtype)

        # --- combine: scatter-add back to token slots ---
        out_col = jnp.zeros((T + 1, D), y.dtype)
        out_col = out_col.at[idx_buf.reshape(-1)].add(y.reshape(-1, D), mode="drop")
        out_col = out_col[:T].reshape(Bl, -1, D)

        if tp:
            if seq_sharded:
                out = jax.lax.psum_scatter(out_col, tp, scatter_dimension=1, tiled=True)
            else:
                out = jax.lax.psum(out_col, tp)
        else:
            out = out_col

        # --- load-balance aux (Switch-style: E * sum_e f_e * p_e) ---
        probs = jax.nn.softmax(logits, axis=-1)
        frac = jnp.mean(
            (jax.nn.one_hot(topk_i[:, 0], E, dtype=jnp.float32)), axis=0
        )
        lb = E * jnp.sum(frac * jnp.mean(probs, axis=0))
        dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
        return out, lb, dropped

    seq_spec = tp if seq_sharded else None
    in_specs = (
        P(dp or None, seq_spec, None),                 # x
        P(),                                           # router
        P(tp, fsdp, None),                             # w_gate (E, D, F)
        P(tp, fsdp, None),                             # w_up
        P(tp, None, fsdp),                             # w_down (E, F, D)
    )
    out_specs = (P(dp or None, seq_spec, None), P(), P())
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
    out, lb, dropped = fn(x, router_w, w_gate, w_up, w_down)
    return out.astype(x.dtype), {"lb_loss": lb, "drop_frac": dropped}


def moe_ffn_reference(x, router_w, w_gate, w_up, w_down, *, cfg: ArchConfig):
    """Dense oracle: every expert computed for every token, no capacity.

    Used by tests; differs from moe_ffn only via capacity drops (tests use
    a capacity factor that guarantees no drops).
    """
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w.astype(jnp.float32))
    topk_w, topk_i = jax.lax.top_k(logits, K)
    topk_w = jax.nn.softmax(topk_w, axis=-1)
    weights = jnp.zeros((xt.shape[0], E), jnp.float32)
    weights = weights.at[jnp.arange(xt.shape[0])[:, None], topk_i].set(topk_w)
    g = jnp.einsum("td,edf->tef", xt, w_gate.astype(xt.dtype))
    u = jnp.einsum("td,edf->tef", xt, w_up.astype(xt.dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, w_down.astype(xt.dtype))
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), weights)
    return out.reshape(B, S, D).astype(x.dtype)
