"""Family registry: uniform model API + abstract input builders.

Every family module exposes:
  param_specs(cfg) / init(cfg, key)
  loss_fn(cfg, mesh, rules, params, batch, *, remat)
  prefill(cfg, mesh, rules, params, tokens, extra, *, max_len)
  decode_step(cfg, mesh, rules, params, cache, tokens, cur_index)
  make_cache_specs(cfg, batch, max_len) / cache_pspec(cfg, dec_sharding)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from . import lm, whisper, xlstm_lm, zamba
from .attention import DecodeSharding
from .common import ShardRules, spec_tree_to_pspecs, spec_tree_to_sds

_FAMILIES = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "hybrid": zamba,
    "ssm": xlstm_lm,
    "audio": whisper,
}


def get_module(cfg: ArchConfig):
    return _FAMILIES[cfg.family]


def supports_slot_serving(cfg: ArchConfig) -> bool:
    """Whether the family works with the continuous-batching serve engine.

    A family qualifies by exposing ``prefill_slot`` (write one lane of the
    slotted cache at a traced lane id) and a ``decode_step`` that accepts
    a vector ``cur_index`` — the cache *contents* don't matter: the lm
    families serve a seq-axis KV cache, ``ssm`` (xLSTM) a pure per-lane
    recurrent state, and ``hybrid`` (Zamba) a composed cache carrying
    both (see :func:`state_kind`).  Only the modality frontends (vlm /
    audio) are excluded — they feed extra per-request inputs the slot
    path doesn't carry yet — and fall back to
    ``serve.loop.generate_static``.
    """
    return cfg.family in ("dense", "moe", "ssm", "hybrid") and hasattr(
        get_module(cfg), "prefill_slot")


def supports_paged_serving(cfg: ArchConfig) -> bool:
    """Whether the family supports the paged (block-table) KV layout —
    needs the paged decode/prefill entry points on top of slot serving.
    Recurrent state kinds never qualify: their per-lane state is O(1) in
    sequence length, so there is no seq axis to page."""
    return supports_slot_serving(cfg) and hasattr(
        get_module(cfg), "decode_step_paged")


def state_kind(cfg: ArchConfig) -> str:
    """Per-lane decode-state kind the serve engine must manage:

    ``"kv"``         a seq-axis KV cache (lm families) — pageable,
                     prefix-shareable, lazily overwritten.
    ``"recurrent"``  O(1)-in-seq per-lane state (ssm/xlstm) — slotted
                     only, hard-reset at admission, zeroed at eviction.
    ``"hybrid"``     both at once (zamba): each lane composes a slotted
                     KV segment with recurrent leaves in one cache dict.
    """
    return getattr(get_module(cfg), "STATE_KIND", "kv")


def recurrent_leaf_axes(cfg: ArchConfig) -> dict:
    """{cache leaf name -> lane axis} for the *recurrent* leaves of the
    family's slot cache (empty for pure-KV families).  The serve engine's
    decode program zeroes these leaves for inactive lanes."""
    fn = getattr(get_module(cfg), "recurrent_leaf_axes", None)
    return fn(cfg) if fn else {}


def lane_leaf_axes(cfg: ArchConfig) -> dict:
    """{cache leaf name -> lane axis} covering *everything* one lane owns
    in the family's slotted cache (KV segments and recurrent leaves
    alike).  This is the host tier's spill unit for non-paged layouts: a
    lane snapshot is one ``dynamic_index_in_dim`` per leaf at these axes.
    Empty for families that don't declare it (no lane spill; preempt
    falls back to decode replay)."""
    fn = getattr(get_module(cfg), "lane_leaf_axes", None)
    return fn(cfg) if fn else {}


def abstract_params(cfg: ArchConfig):
    return spec_tree_to_sds(get_module(cfg).param_specs(cfg))


def param_pspecs(cfg: ArchConfig, rules: ShardRules):
    return spec_tree_to_pspecs(get_module(cfg).param_specs(cfg), rules)


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct + PartitionSpec) per shape kind
# ---------------------------------------------------------------------------


def _extra_key(cfg: ArchConfig) -> str | None:
    if cfg.family == "vlm":
        return "patch_embeds"
    if cfg.family == "audio":
        return "frames"
    return None


def train_inputs(cfg: ArchConfig, shape: ShapeConfig, rules: ShardRules):
    """Returns ({name: sds}, {name: pspec}) for the training batch."""
    B, S = shape.global_batch, shape.seq_len
    sds, ps = {}, {}
    s_text = S - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    sds["tokens"] = jax.ShapeDtypeStruct((B, s_text + 1), jnp.int32)
    ps["tokens"] = rules.pspec("dp", None)
    if cfg.family == "vlm":
        sds["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.dtype(cfg.compute_dtype)
        )
        ps["patch_embeds"] = rules.pspec("dp", None, None)
    if cfg.family == "audio":
        sds["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
        ps["frames"] = rules.pspec("dp", None, None)
    return sds, ps


def prefill_inputs(cfg: ArchConfig, shape: ShapeConfig, rules: ShardRules):
    B, S = shape.global_batch, shape.seq_len
    s_text = S - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    sds = {"tokens": jax.ShapeDtypeStruct((B, s_text), jnp.int32)}
    ps = {"tokens": rules.pspec("dp", None)}
    k = _extra_key(cfg)
    if k == "patch_embeds":
        sds[k] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.dtype(cfg.compute_dtype))
        ps[k] = rules.pspec("dp", None, None)
    elif k == "frames":
        sds[k] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        ps[k] = rules.pspec("dp", None, None)
    return sds, ps


def decode_inputs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """(cache sds/pspec, token sds/pspec, cur_index sds)."""
    B, S = shape.global_batch, shape.seq_len
    mod = get_module(cfg)
    dec = DecodeSharding.choose(mesh, B)
    cache_sds = mod.make_cache_specs(cfg, B, S)
    cache_ps = mod.cache_pspec(cfg, dec)
    tok_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_ps = P(dec.batch_axes or None)
    return cache_sds, cache_ps, tok_sds, tok_ps
