"""Mamba2 (SSD) blocks — chunked, matmul-based state-space scan.

The SSD ("state-space duality") form computes the selective-SSM with
chunk-local attention-like matmuls plus an inter-chunk state recurrence:
MXU-friendly on TPU (the Pallas kernel kernels/ssd mirrors this blocking).

Shapes follow Mamba2: x (B,T,H,P); dt (B,T,H); A (H,) negative;
B/C (B,T,G,N) with H % G == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .common import ParamSpec, ShardRules, constrain, rms_norm


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, return_state: bool = False):
    """Returns y (B,T,H,P) (and the final SSM state if requested)."""
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, T)
    T_real = T
    if T % Q:
        # pad with dt=0 steps: decay=exp(0)=1 and input weight dt=0, so the
        # padded tail is an identity on the state and the outputs slice off
        pad = Q - T % Q
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, Bm, Cm = zpad(x), zpad(dt), zpad(Bm), zpad(Cm)
        T = T + pad
    nc = T // Q

    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)    # (B,T,H,N)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bh.reshape(Bsz, nc, Q, H, N)
    Cc = Ch.reshape(Bsz, nc, Q, H, N)

    la = dtc * A                                # (B,nc,Q,H) log-decay <= 0
    cum = jnp.cumsum(la, axis=2)                # inclusive within chunk
    seg_total = cum[:, :, -1]                   # (B,nc,H)

    xdt = xc * dtc[..., None]                   # dt-weighted inputs

    # --- intra-chunk: Y[q] += sum_{k<=q} exp(cum[q]-cum[k]) C_q.B_k x_k ---
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
    decay = jnp.exp(
        cum.transpose(0, 1, 3, 2)[..., :, None] - cum.transpose(0, 1, 3, 2)[..., None, :]
    )                                            # (B,nc,H,Q,K)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    scores = jnp.where(mask, scores * decay, 0.0)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xdt)

    # --- chunk states: S_c = sum_k exp(seg_total - cum[k]) B_k (x_k)^T ---
    w_state = jnp.exp(seg_total[:, :, None, :] - cum)        # (B,nc,Q,H)
    states = jnp.einsum("bckhn,bckhp->bchnp", Bc * w_state[..., None], xdt)

    # --- inter-chunk recurrence over chunk index ---
    def step(S, inp):
        st, g = inp                              # st: (B,H,N,P), g: (B,H)
        S_new = S * jnp.exp(g)[..., None, None] + st
        return S_new, S                          # emit state BEFORE this chunk

    S0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    S_final, S_prev = jax.lax.scan(
        step, S0,
        (states.transpose(1, 0, 2, 3, 4), seg_total.transpose(1, 0, 2)),
    )
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)     # (B,nc,H,N,P)

    # --- inter contribution: Y[q] += exp(cum[q]) C_q . S_prev ---
    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp", Cc * jnp.exp(cum)[..., None], S_prev
    )
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)[:, :T_real]
    if return_state:
        return y, S_final
    return y


def ssd_reference(x, dt, A, Bm, Cm):
    """Step-by-step recurrence oracle (tests)."""
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    dt = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, bt, ct = inp                    # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dtt * A)                 # (B,H)
        S = S * decay[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt * dtt[..., None]
        )
        y = jnp.einsum("bhn,bhnp->bhp", ct, S)
        return S, y

    S0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, S0,
        (xf.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3)),
    )
    return ys.transpose(1, 0, 2, 3)


def ssd_decode_step(S, x, dt, A, Bm, Cm):
    """One-token state update.  S: (B,H,N,P); x: (B,H,P); dt: (B,H);
    Bm/Cm: (B,G,N).  Returns (S', y (B,H,P))."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))
    S = S * decay[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh, x.astype(jnp.float32) * dt[..., None]
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, S)
    return S, y


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.state
    return d_inner, H, conv_ch


def mamba_block_specs(cfg: ArchConfig, n_layers: int) -> dict:
    """Stacked (n_layers, ...) Mamba2 block parameters."""
    D = cfg.d_model
    s = cfg.ssm
    d_inner, H, conv_ch = mamba_dims(cfg)
    L = (n_layers,)
    ll = (None,)
    dt = jnp.dtype(cfg.param_dtype)
    d_proj = 2 * d_inner + 2 * s.n_groups * s.state + H
    return {
        "ln": ParamSpec(L + (D,), ll + (None,), dt, init_scale=0.0),
        "in_proj": ParamSpec(L + (D, d_proj), ll + ("fsdp", "tp"), dt),
        "conv_w": ParamSpec(L + (s.conv_kernel, conv_ch), ll + (None, "tp"), dt),
        "conv_b": ParamSpec(L + (conv_ch,), ll + ("tp",), dt, init_scale=0.0),
        "dt_bias": ParamSpec(L + (H,), ll + (None,), dt, init_scale=0.0),
        "A_log": ParamSpec(L + (H,), ll + (None,), dt, init_scale=0.0),
        "D_skip": ParamSpec(L + (H,), ll + (None,), dt, init_scale=0.0),
        "out_ln": ParamSpec(L + (d_inner,), ll + (None,), dt, init_scale=0.0),
        "out_proj": ParamSpec(L + (d_inner, D), ll + ("tp", "fsdp"), dt),
    }


def _split_proj(cfg: ArchConfig, proj):
    s = cfg.ssm
    d_inner, H, _ = mamba_dims(cfg)
    gn = s.n_groups * s.state
    z, xs, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn], axis=-1
    )
    return z, xs, b, c, dt


def _causal_conv(x, w, b, state=None, state_len=None):
    """Depthwise causal conv.  x: (B,T,C); w: (K,C); state: (B,K-1,C)|None.

    Returns (y, new_state) — new_state is the last K-1 inputs.  With
    ``state_len`` (a traced position, 1 <= state_len <= T) the state is
    instead the K-1 inputs *preceding position state_len*: the slotted
    serve engine prefills a right-padded length bucket, and the carried
    conv state must snapshot the real prompt end, not the padded tail.
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K)
    ) + b[None, None, :]
    if K <= 1:
        new_state = state
    elif state_len is None:
        new_state = xp[:, -(K - 1):]
    else:
        # xp[state_len : state_len + K - 1] = inputs at positions
        # [state_len - (K-1), state_len) — bitwise what an exact-length
        # (T == state_len) prefill would have carried
        new_state = jax.lax.dynamic_slice_in_dim(xp, state_len, K - 1, axis=1)
    return y, new_state


def mamba_block_fwd(cfg: ArchConfig, rules: ShardRules, x, bp, *,
                    return_state: bool = False, valid=None, state_len=None):
    """x: (B,T,D).  Returns x + mamba(x) (and (ssm, conv) final states).

    ``valid`` ((B,T) bool) marks real positions of a right-padded prompt
    bucket (slotted serve prefill): padded steps get ``dt = 0``, which is
    an *exact* identity on the SSD recurrence (decay ``exp(0) = 1``,
    input weight 0) — the same mechanism ``ssd_chunked`` uses for its own
    chunk padding — so the carried state is bitwise the state at the end
    of the real prompt.  ``state_len`` snapshots the conv state there too.
    """
    s = cfg.ssm
    d_inner, H, _ = mamba_dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, bp["ln"], cfg.norm_eps)
    proj = jnp.einsum("btd,dk->btk", h, bp["in_proj"].astype(cdt))
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, bp["conv_w"].astype(cdt), bp["conv_b"].astype(cdt),
        state_len=state_len,
    )
    conv_out = jax.nn.silu(conv_out)
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.state], axis=-1)

    B_, T = x.shape[:2]
    xh = xs.reshape(B_, T, H, s.head_dim)
    bm = bmat.reshape(B_, T, s.n_groups, s.state)
    cm = cmat.reshape(B_, T, s.n_groups, s.state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + bp["dt_bias"].astype(jnp.float32))
    if valid is not None:
        dtv = jnp.where(valid[..., None], dtv, 0.0)
    A = -jnp.exp(bp["A_log"].astype(jnp.float32))
    y, ssm_state = ssd_chunked(xh, dtv, A, bm, cm, chunk=s.chunk, return_state=True)
    y = y + bp["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, T, d_inner).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), bp["out_ln"], cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, bp["out_proj"].astype(cdt))
    out = constrain(x + out, rules, "dp", "sp", None)
    if return_state:
        return out, (ssm_state, conv_state)
    return out


def mamba_state_specs(cfg: ArchConfig, n_layers: int, batch: int):
    s = cfg.ssm
    d_inner, H, conv_ch = mamba_dims(cfg)
    return {
        "ssm": jax.ShapeDtypeStruct((n_layers, batch, H, s.state, s.head_dim), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (n_layers, batch, s.conv_kernel - 1, conv_ch), jnp.dtype(cfg.compute_dtype)
        ),
    }


def mamba_block_decode(cfg: ArchConfig, rules: ShardRules, x, bp, ssm_state, conv_state):
    """x: (B,D) one token.  Returns (x', ssm_state', conv_state')."""
    s = cfg.ssm
    d_inner, H, _ = mamba_dims(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, bp["ln"], cfg.norm_eps)
    proj = jnp.einsum("bd,dk->bk", h, bp["in_proj"].astype(cdt))
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)[:, None]
    conv_out, conv_state = _causal_conv(
        conv_in, bp["conv_w"].astype(cdt), bp["conv_b"].astype(cdt), conv_state
    )
    conv_out = jax.nn.silu(conv_out[:, 0])
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.state], axis=-1)
    B_ = x.shape[0]
    xh = xs.reshape(B_, H, s.head_dim)
    bm = bmat.reshape(B_, s.n_groups, s.state)
    cm = cmat.reshape(B_, s.n_groups, s.state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + bp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(bp["A_log"].astype(jnp.float32))
    ssm_state, y = ssd_decode_step(ssm_state, xh, dtv, A, bm, cm)
    y = y + bp["D_skip"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, d_inner).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), bp["out_ln"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, bp["out_proj"].astype(cdt))
    return x + out, ssm_state, conv_state
