"""Whisper-style encoder-decoder [arXiv:2212.04356].

The conv frontend is a STUB per the task spec: inputs are precomputed
frame embeddings (B, enc_seq, d_model) standing in for the 2x conv1d
features.  Encoder: bidirectional attention + sinusoidal positions.
Decoder: causal self-attention (RoPE — an adaptation of Whisper's learned
positions, noted in DESIGN.md) + cross-attention + GELU MLP.  Embeddings
tied with the output head, as in the published model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .attention import DecodeSharding, chunked_attention, decode_attention, rope
from .common import (
    ParamSpec, ShardRules, constrain, cross_entropy_loss, init_tree, rms_norm,
)


def _attn_specs(cfg, L, ll, dt, prefix=""):
    D, dh, H, Hk = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv
    return {
        prefix + "wq": ParamSpec(L + (D, H * dh), ll + ("fsdp", "tp"), dt),
        prefix + "wk": ParamSpec(L + (D, Hk * dh), ll + ("fsdp", "tp"), dt),
        prefix + "wv": ParamSpec(L + (D, Hk * dh), ll + ("fsdp", "tp"), dt),
        prefix + "wo": ParamSpec(L + (H * dh, D), ll + ("tp", "fsdp"), dt),
    }


def _mlp_specs(cfg, L, ll, dt):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamSpec(L + (D, F), ll + ("fsdp", "tp"), dt),
        "w2": ParamSpec(L + (F, D), ll + ("tp", "fsdp"), dt),
    }


def padded_vocab(cfg: ArchConfig) -> int:
    """Whisper's 51865-token vocab is odd; pad the (tied) embedding table to
    a 256-multiple so it shards over the tp axis.  Labels never reference
    the padding, so the CE over the extended vocab is exact."""
    return int(np.ceil(cfg.vocab / 256) * 256)


def param_specs(cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    Le, Ld = (cfg.enc_layers,), (cfg.n_layers,)
    ll = (None,)
    enc = {
        "ln1": ParamSpec(Le + (D,), ll + (None,), dt, init_scale=0.0),
        "ln2": ParamSpec(Le + (D,), ll + (None,), dt, init_scale=0.0),
        **_attn_specs(cfg, Le, ll, dt),
        **_mlp_specs(cfg, Le, ll, dt),
    }
    dec = {
        "ln1": ParamSpec(Ld + (D,), ll + (None,), dt, init_scale=0.0),
        "lnx": ParamSpec(Ld + (D,), ll + (None,), dt, init_scale=0.0),
        "ln2": ParamSpec(Ld + (D,), ll + (None,), dt, init_scale=0.0),
        **_attn_specs(cfg, Ld, ll, dt),
        **_attn_specs(cfg, Ld, ll, dt, prefix="x_"),
        **_mlp_specs(cfg, Ld, ll, dt),
    }
    return {
        "embed": ParamSpec((padded_vocab(cfg), D), ("tp", "fsdp"), dt),
        "enc": enc,
        "dec": dec,
        "enc_ln_f": ParamSpec((D,), (None,), dt, init_scale=0.0),
        "ln_f": ParamSpec((D,), (None,), dt, init_scale=0.0),
    }


def init(cfg: ArchConfig, key) -> dict:
    return init_tree(key, param_specs(cfg))


def _sinusoid(T: int, D: int, dtype):
    pos = np.arange(T)[:, None]
    dim = np.arange(D // 2)[None, :]
    ang = pos / (10_000 ** (2 * dim / D))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)


def _mha(cfg, bp, prefix, xq, xkv, *, causal):
    """Full attention between xq (B,Sq,D) and xkv (B,Sk,D)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, Sq, D = xq.shape
    Sk = xkv.shape[1]
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    q = jnp.einsum("bsd,dk->bsk", xq, bp[prefix + "wq"].astype(cdt)).reshape(B, Sq, H, dh)
    k = jnp.einsum("bsd,dk->bsk", xkv, bp[prefix + "wk"].astype(cdt)).reshape(B, Sk, Hk, dh)
    v = jnp.einsum("bsd,dk->bsk", xkv, bp[prefix + "wv"].astype(cdt)).reshape(B, Sk, Hk, dh)
    if causal:  # decoder self-attention: rotary positions
        pos_q = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        pos_k = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
        q, k = rope(q, pos_q, cfg.rope_theta), rope(k, pos_k, cfg.rope_theta)
    out = chunked_attention(
        q, k, v, causal=causal,
        q_chunk=min(256, Sq), kv_chunk=min(256, Sk),
    )
    o = jnp.einsum("bsk,kd->bsd", out.reshape(B, Sq, -1), bp[prefix + "wo"].astype(cdt))
    return o, (k, v)


def _mlp(cfg, bp, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    h = jnp.einsum("bsd,df->bsf", x, bp["w1"].astype(cdt))
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(h), bp["w2"].astype(cdt))


def encode(cfg, mesh, rules, params, frames):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) + _sinusoid(frames.shape[1], cfg.d_model, cdt)[None]
    x = constrain(x, rules, "dp", None, None)

    def body(x, bp):
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        o, _ = _mha(cfg, bp, "", h, h, causal=False)
        x = x + o
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        return x + _mlp(cfg, bp, h), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def decode_train(cfg, mesh, rules, params, tokens, enc_out, *, remat=True,
                 collect_kv=False):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"].astype(cdt), tokens, axis=0)
    x = constrain(x, rules, "dp", None, None)

    def body(x, bp):
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        o, kv = _mha(cfg, bp, "", h, h, causal=True)
        x = x + o
        h = rms_norm(x, bp["lnx"], cfg.norm_eps)
        o, xkv = _mha(cfg, bp, "x_", h, enc_out, causal=False)
        x = x + o
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + _mlp(cfg, bp, h)
        ys = (kv, xkv) if collect_kv else None
        return x, ys

    from .common import remat_wrap
    body = remat_wrap(body, remat)
    x, kvs = jax.lax.scan(body, x, params["dec"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps), kvs


def _logits(cfg, rules, params, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("...d,vd->...v", x, params["embed"].astype(cdt))
    return constrain(logits, rules, *( ("dp", None, "tp") if logits.ndim == 3 else ("dp", "tp") ))


def loss_fn(cfg, mesh, rules, params, batch, *, remat=True):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(cfg, mesh, rules, params, batch["frames"])
    hidden, _ = decode_train(cfg, mesh, rules, params, inp, enc_out, remat=remat)
    loss = cross_entropy_loss(_logits(cfg, rules, params, hidden), labels)
    return loss, {"ce_loss": loss, "lb_loss": jnp.float32(0.0),
                  "drop_frac": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def make_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    cdt = jnp.dtype(cfg.compute_dtype)
    L = cfg.n_layers
    kv = jax.ShapeDtypeStruct((L, batch, max_len, cfg.n_kv, cfg.head_dim), cdt)
    xkv = jax.ShapeDtypeStruct((L, batch, cfg.enc_seq, cfg.n_kv, cfg.head_dim), cdt)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}


def cache_pspec(cfg: ArchConfig, dec: DecodeSharding):
    from jax.sharding import PartitionSpec as P
    b = dec.batch_axes or None
    s = dec.seq_axes or None
    return {
        "k": P(None, b, s, None, None), "v": P(None, b, s, None, None),
        "xk": P(None, b, None, None, None), "xv": P(None, b, None, None, None),
    }


def prefill(cfg, mesh, rules, params, tokens, frames=None, *, max_len=None):
    enc_out = encode(cfg, mesh, rules, params, frames)
    hidden, ((k, v), (xk, xv)) = decode_train(
        cfg, mesh, rules, params, tokens, enc_out, remat=False, collect_kv=True
    )
    dec = DecodeSharding.choose(mesh, tokens.shape[0])

    def pad(c):
        if max_len and max_len > c.shape[2]:
            pw = [(0, 0)] * c.ndim
            pw[2] = (0, max_len - c.shape[2])
            c = jnp.pad(c, pw)
        return c

    cache = {"k": pad(k), "v": pad(v), "xk": xk, "xv": xv}
    specs = cache_pspec(cfg, dec)
    from .common import constrain_spec
    cache = {n: constrain_spec(c, mesh, specs[n]) for n, c in cache.items()}
    return cache, _logits(cfg, rules, params, hidden[:, -1])


def _cross_decode(cfg, bp, x, xk, xv):
    """Single-token cross attention over the cached encoder K/V."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, D = x.shape
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    q = jnp.einsum("bd,dk->bk", x, bp["x_wq"].astype(cdt)).reshape(B, Hk, H // Hk, dh)
    s = jnp.einsum("bhrd,bshd->bhrs", q.astype(jnp.float32), xk.astype(jnp.float32))
    s = s * (dh ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrs,bshd->bhrd", p, xv.astype(jnp.float32)).astype(cdt)
    return jnp.einsum("bk,kd->bd", o.reshape(B, H * dh), bp["x_wo"].astype(cdt))


def decode_step(cfg, mesh, rules, params, cache, tokens, cur_index):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"].astype(cdt), tokens, axis=0)
    B = x.shape[0]
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    dec = DecodeSharding.choose(mesh, B)

    def body(x, xs):
        bp, kc, vc, xk, xv = xs
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dk->bk", h, bp["wq"].astype(cdt)).reshape(B, H, dh)
        k = jnp.einsum("bd,dk->bk", h, bp["wk"].astype(cdt)).reshape(B, Hk, dh)
        v = jnp.einsum("bd,dk->bk", h, bp["wv"].astype(cdt)).reshape(B, Hk, dh)
        pos = jnp.full((B, 1), cur_index, jnp.int32)
        q = rope(q[:, None], pos, cfg.rope_theta)[:, 0].reshape(B, Hk, H // Hk, dh)
        k = rope(k[:, None], pos, cfg.rope_theta)[:, 0]
        attn, kc, vc = decode_attention(q, kc, vc, k, v, cur_index, sharding=dec)
        x = x + jnp.einsum("bk,kd->bd", attn.reshape(B, H * dh), bp["wo"].astype(cdt))
        h = rms_norm(x, bp["lnx"], cfg.norm_eps)
        x = x + _cross_decode(cfg, bp, h, xk, xv)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        h1 = jnp.einsum("bd,df->bf", h, bp["w1"].astype(cdt))
        x = x + jnp.einsum("bf,fd->bd", jax.nn.gelu(h1), bp["w2"].astype(cdt))
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    new_cache = {"k": k_new, "v": v_new, "xk": cache["xk"], "xv": cache["xv"]}
    return _logits(cfg, rules, params, x), new_cache
