"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise
parallel) and sLSTM (scalar memory, sequential scan with recurrent mixing).

The mLSTM recurrence (per head; C: (dk, dv) matrix memory):

    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) k_t v_t^T
    n_t = exp(logf_t + m_{t-1} - m_t) n_{t-1} + exp(logi_t - m_t) k_t
    h_t = C_t^T q_t / max(|n_t . q_t|, exp(-m_t))

is evaluated chunkwise: intra-chunk terms as (Q x Q) masked matmuls,
inter-chunk state carried as (C*, n*, m*) with the stabiliser folded in —
the same max-rescaling bookkeeping as flash attention, which makes the
block MXU-friendly (the paper's CUDA kernels are fused scans; on TPU the
chunked matmul form is the right adaptation — see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .common import ParamSpec, ShardRules, constrain, rms_norm
from .ssm import _causal_conv

# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_pre, f_pre, *, chunk: int, return_state: bool = False):
    """q/k/v: (B,T,H,Dh); i_pre/f_pre: (B,T,H).  Returns (B,T,H,Dh)
    (and the final (C, n, m) cell state if requested)."""
    B, T, H, Dh = q.shape
    Q = min(chunk, T)
    T_real = T
    if T % Q:
        # identity padding: f -> 1 (f_pre large +), i -> 0 (i_pre large -)
        pad = Q - T % Q
        zpad = lambda a, val=0.0: jnp.pad(
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
            constant_values=val)
        q, k, v = zpad(q), zpad(k), zpad(v)
        i_pre, f_pre = zpad(i_pre, -1e30), zpad(f_pre, 30.0)
        T = T + pad
    nc = T // Q

    qf = q.astype(jnp.float32) * (Dh ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))     # (B,T,H)
    logi = i_pre.astype(jnp.float32)

    qc = qf.reshape(B, nc, Q, H, Dh).transpose(1, 0, 3, 2, 4)   # (nc,B,H,Q,Dh)
    kc = kf.reshape(B, nc, Q, H, Dh).transpose(1, 0, 3, 2, 4)
    vc = vf.reshape(B, nc, Q, H, Dh).transpose(1, 0, 3, 2, 4)
    lfc = logf.reshape(B, nc, Q, H).transpose(1, 0, 3, 2)       # (nc,B,H,Q)
    lic = logi.reshape(B, nc, Q, H).transpose(1, 0, 3, 2)

    def chunk_step(carry, inp):
        Cs, ns, ms = carry            # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qi, ki, vi, lf, li = inp
        b = jnp.cumsum(lf, axis=-1)                      # (B,H,Q) inclusive
        g = li - b                                       # (B,H,Q)
        gmax = jax.lax.cummax(g, axis=g.ndim - 1)
        M = b + jnp.maximum(ms[..., None], gmax)         # (B,H,Q) row stabiliser

        # intra: w[t,s] = exp(b_t - b_s + li_s - M_t), s <= t
        w = jnp.exp(b[..., :, None] - b[..., None, :] + li[..., None, :] - M[..., :, None])
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        w = jnp.where(mask, w, 0.0)
        qk = jnp.einsum("bhtd,bhsd->bhts", qi, ki)
        scores = qk * w
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vi)
        den = jnp.einsum("bhts->bht", scores)

        # inter: stored state scaled by exp(ms); contribution exp(ms + b_t - M_t)
        scale = jnp.exp(ms[..., None] + b - M)           # (B,H,Q)
        num = num + jnp.einsum("bhtd,bhde->bhte", qi * scale[..., None], Cs)
        den = den + jnp.einsum("bhtd,bhd->bht", qi * scale[..., None], ns)

        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-M))[..., None]

        # end-of-chunk state
        btot = b[..., -1]                                # (B,H)
        m_new = btot + jnp.maximum(ms, jnp.max(g, axis=-1))
        wst = jnp.exp(btot[..., None] - b + li - m_new[..., None])   # (B,H,Q)
        C_new = Cs * jnp.exp(ms + btot - m_new)[..., None, None] + jnp.einsum(
            "bhsd,bhse->bhde", ki * wst[..., None], vi
        )
        n_new = ns * jnp.exp(ms + btot - m_new)[..., None] + jnp.einsum(
            "bhsd,bhs->bhd", ki, wst
        )
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    n0 = jnp.zeros((B, H, Dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    final, hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lfc, lic))
    # hs: (nc, B, H, Q, Dh) -> (B, T, H, Dh)
    y = hs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, Dh)[:, :T_real].astype(q.dtype)
    if return_state:
        return y, final
    return y


def mlstm_reference(q, k, v, i_pre, f_pre):
    """Per-step recurrence oracle."""
    B, T, H, Dh = q.shape
    qf = q.astype(jnp.float32) * (Dh ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    logi = i_pre.astype(jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lf, li = inp
        m_new = jnp.maximum(lf + m, li)
        fs = jnp.exp(lf + m - m_new)
        is_ = jnp.exp(li - m_new)
        C = C * fs[..., None, None] + is_[..., None, None] * jnp.einsum("bhd,bhe->bhde", kt, vt)
        n = n * fs[..., None] + is_[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    n0 = jnp.zeros((B, H, Dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(
        step, (C0, n0, m0),
        (qf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3), vf.transpose(1, 0, 2, 3),
         logf.transpose(1, 0, 2), logi.transpose(1, 0, 2)),
    )
    return hs.transpose(1, 0, 2, 3).astype(q.dtype)


def mlstm_decode_step(state, qt, kt, vt, i_pre, f_pre):
    """state: (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)); one token step."""
    C, n, m = state
    Dh = qt.shape[-1]
    qf = qt.astype(jnp.float32) * (Dh ** -0.5)
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    li = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_new)
    is_ = jnp.exp(li - m_new)
    C = C * fs[..., None, None] + is_[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", kt.astype(jnp.float32), vt.astype(jnp.float32))
    n = n * fs[..., None] + is_[..., None] * kt.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    return (C, n, m_new), (num / den[..., None]).astype(qt.dtype)


# ---------------------------------------------------------------------------
# sLSTM cell (sequential scan; block-diagonal recurrent mixing)
# ---------------------------------------------------------------------------


def slstm_scan(x_z, x_i, x_f, x_o, r_z, r_i, r_f, r_o, h0, c0, n0, m0,
               valid=None):
    """x_*: (B,T,H,Dh) pre-activations from the input path;
    r_*: (H,Dh,Dh) recurrent (block-diagonal head mixing) weights.
    Returns (h (B,T,H,Dh), final_state).

    ``valid`` ((B,T) bool): steps where it is False leave the carried
    state untouched (``where`` keeps the old carry bitwise), so a
    right-padded prompt bucket carries out exactly the state at the end
    of the real prompt — what the slotted serve engine's bucketed prefill
    needs.

    NOTE (EXPERIMENTS.md §Perf E): under SPMD the scan transpose reduces
    dR = h x delta across the batch axes EVERY step. Passing R through the
    scan carry does not help — XLA's loop-invariant-code motion hoists it
    back (verified: bit-identical HLO).  The real fix is a chunk-unrolled
    sLSTM cell or a Pallas bwd kernel with a local dR accumulator."""

    def step(carry, inp):
        h, c, n, m = carry
        if valid is None:
            xz, xi, xf, xo = inp
        else:
            xz, xi, xf, xo, vt = inp
        zt = jnp.tanh(xz + jnp.einsum("bhd,hde->bhe", h, r_z))
        it = xi + jnp.einsum("bhd,hde->bhe", h, r_i)
        ft = xf + jnp.einsum("bhd,hde->bhe", h, r_f)
        ot = jax.nn.sigmoid(xo + jnp.einsum("bhd,hde->bhe", h, r_o))
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        if valid is not None:
            keep = vt[:, None, None]
            h_new = jnp.where(keep, h_new, h)
            c_new = jnp.where(keep, c_new, c)
            n_new = jnp.where(keep, n_new, n)
            m_new = jnp.where(keep, m_new, m)
        return (h_new, c_new, n_new, m_new), h_new

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (x_z, x_i, x_f, x_o))
    if valid is not None:
        xs = xs + (valid.T,)
    # unroll: gives XLA's AllReduceReassociate a window to merge the
    # per-step dR reductions in the transpose (8 psums -> 1 per window)
    T = x_z.shape[1]
    unroll = 8 if T % 8 == 0 else 1
    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs, unroll=unroll)
    return hs.transpose(1, 0, 2, 3), (h, c, n, m)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def xlstm_dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model          # mLSTM projection factor 2
    dh_m = d_inner // cfg.n_heads
    dh_s = cfg.d_model // cfg.n_heads
    return d_inner, dh_m, dh_s


def mlstm_block_specs(cfg: ArchConfig, n: int) -> dict:
    D = cfg.d_model
    d_inner, dh, _ = xlstm_dims(cfg)
    H = cfg.n_heads
    L, ll = (n,), (None,)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln": ParamSpec(L + (D,), ll + (None,), dt, init_scale=0.0),
        "w_up": ParamSpec(L + (D, 2 * d_inner), ll + ("fsdp", "tp"), dt),
        "conv_w": ParamSpec(L + (4, d_inner), ll + (None, "tp"), dt),
        "conv_b": ParamSpec(L + (d_inner,), ll + ("tp",), dt, init_scale=0.0),
        "wq": ParamSpec(L + (d_inner, d_inner), ll + ("fsdp", "tp"), dt),
        "wk": ParamSpec(L + (d_inner, d_inner), ll + ("fsdp", "tp"), dt),
        "wv": ParamSpec(L + (d_inner, d_inner), ll + ("fsdp", "tp"), dt),
        "w_gates": ParamSpec(L + (d_inner, 2 * H), ll + ("fsdp", None), dt),
        "b_gates": ParamSpec(L + (2 * H,), ll + (None,), dt, init_scale=0.0),
        "out_ln": ParamSpec(L + (d_inner,), ll + (None,), dt, init_scale=0.0),
        "w_down": ParamSpec(L + (d_inner, D), ll + ("tp", "fsdp"), dt),
    }


def slstm_block_specs(cfg: ArchConfig, n: int) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    f = max(int(np.ceil(4 * D / 3 / 64) * 64), 64)   # 4/3 GLU, lane-aligned
    L, ll = (n,), (None,)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln": ParamSpec(L + (D,), ll + (None,), dt, init_scale=0.0),
        "conv_w": ParamSpec(L + (4, D), ll + (None, None), dt),
        "conv_b": ParamSpec(L + (D,), ll + (None,), dt, init_scale=0.0),
        "w_in": ParamSpec(L + (D, 4 * D), ll + ("fsdp", "tp"), dt),
        "b_in": ParamSpec(L + (4 * D,), ll + (None,), dt, init_scale=0.0),
        "r_z": ParamSpec(L + (H, dh, dh), ll + (None, None, None), dt),
        "r_i": ParamSpec(L + (H, dh, dh), ll + (None, None, None), dt),
        "r_f": ParamSpec(L + (H, dh, dh), ll + (None, None, None), dt),
        "r_o": ParamSpec(L + (H, dh, dh), ll + (None, None, None), dt),
        "out_ln": ParamSpec(L + (D,), ll + (None,), dt, init_scale=0.0),
        "w_up1": ParamSpec(L + (D, f), ll + ("fsdp", "tp"), dt),
        "w_up2": ParamSpec(L + (D, f), ll + ("fsdp", "tp"), dt),
        "w_down": ParamSpec(L + (f, D), ll + ("tp", "fsdp"), dt),
    }


def mlstm_block_fwd(cfg, rules, x, bp, *, chunk: int = 128, conv_state=None,
                    cell_state=None, decode: bool = False, valid=None,
                    state_len=None):
    """x: (B,T,D) (T=1 with states for decode).  Returns (x', states).

    ``valid`` ((B,T) bool) marks the real positions of a right-padded
    prompt bucket (slotted serve prefill).  Padded steps are forced to an
    *exact* cell identity: ``i_pre -> -1e30`` (input contribution
    ``exp(-1e30 - m) == 0``) and ``f_pre -> 1e30`` (``log_sigmoid == -0.0``,
    so the log-decay cumsum is bit-unchanged) — the carried (C, n, m) is
    bitwise the state at the end of the real prompt.  ``state_len``
    snapshots the conv state at that position.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    d_inner, dh, _ = xlstm_dims(cfg)
    H = cfg.n_heads
    B, T = x.shape[:2]
    h = rms_norm(x, bp["ln"], cfg.norm_eps)
    up = jnp.einsum("btd,dk->btk", h, bp["w_up"].astype(cdt))
    a, z = jnp.split(up, 2, axis=-1)
    c, conv_state = _causal_conv(
        a, bp["conv_w"].astype(cdt), bp["conv_b"].astype(cdt), conv_state,
        state_len=state_len)
    c = jax.nn.silu(c)
    q = jnp.einsum("btk,kj->btj", c, bp["wq"].astype(cdt)).reshape(B, T, H, dh)
    k = jnp.einsum("btk,kj->btj", c, bp["wk"].astype(cdt)).reshape(B, T, H, dh)
    v = jnp.einsum("btk,kj->btj", a, bp["wv"].astype(cdt)).reshape(B, T, H, dh)
    gates = jnp.einsum("btk,kj->btj", a, bp["w_gates"].astype(cdt)) + bp["b_gates"].astype(cdt)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)          # (B,T,H)
    if valid is not None:
        i_pre = jnp.where(valid[..., None], i_pre, -1e30)
        f_pre = jnp.where(valid[..., None], f_pre, 1e30)

    if decode:
        cell_state, y = mlstm_decode_step(
            cell_state, q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]
        )
        y = y[:, None]
    else:
        y, cell_state = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=chunk,
                                      return_state=True)
    y = y.reshape(B, T, d_inner)
    y = rms_norm(y, bp["out_ln"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("btk,kd->btd", y, bp["w_down"].astype(cdt))
    out = constrain(x + out, rules, "dp", "sp", None)
    return out, (conv_state, cell_state)


def slstm_block_fwd(cfg, rules, x, bp, *, conv_state=None, cell_state=None,
                    decode: bool = False, valid=None, state_len=None):
    """``valid``/``state_len``: see :func:`mlstm_block_fwd` — the sLSTM
    scan freezes its carry on padded steps instead of gate overrides."""
    cdt = jnp.dtype(cfg.compute_dtype)
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    B, T = x.shape[:2]
    h = rms_norm(x, bp["ln"], cfg.norm_eps)
    c, conv_state = _causal_conv(
        h, bp["conv_w"].astype(cdt), bp["conv_b"].astype(cdt), conv_state,
        state_len=state_len)
    c = jax.nn.silu(c)
    pre = jnp.einsum("btd,dk->btk", c, bp["w_in"].astype(cdt)) + bp["b_in"].astype(cdt)
    xz, xi, xf, xo = [p.reshape(B, T, H, dh) for p in jnp.split(pre, 4, axis=-1)]

    if cell_state is None:
        h0 = jnp.zeros((B, H, dh), jnp.float32)
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H, dh), -1e30, jnp.float32)
    else:
        h0, c0, n0, m0 = cell_state
    rz, ri, rf, ro = (bp[k_].astype(jnp.float32) for k_ in ("r_z", "r_i", "r_f", "r_o"))
    hs, cell_state = slstm_scan(xz, xi, xf, xo, rz, ri, rf, ro, h0, c0, n0, m0,
                                valid=valid)
    y = hs.reshape(B, T, D).astype(cdt)
    y = rms_norm(y, bp["out_ln"], cfg.norm_eps)
    g = jnp.einsum("btd,df->btf", y, bp["w_up1"].astype(cdt))
    u = jnp.einsum("btd,df->btf", y, bp["w_up2"].astype(cdt))
    out = jnp.einsum("btf,fd->btd", jax.nn.gelu(g) * u, bp["w_down"].astype(cdt))
    out = constrain(x + out, rules, "dp", "sp", None)
    return out, (conv_state, cell_state)
