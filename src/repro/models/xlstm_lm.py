"""xLSTM-LM assembly [arXiv:2405.04517]: ``slstm_every - 1`` mLSTM blocks
followed by one sLSTM block, repeated (7:1 ratio for xlstm-1.3b).
Attention-free: decoding is O(1)-state, which is what qualifies this arch
for the 500k-token long-context shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .common import (
    ParamSpec, ShardRules, constrain, cross_entropy_loss, init_tree, rms_norm,
)
from .xlstm import (
    mlstm_block_fwd, mlstm_block_specs, slstm_block_fwd, slstm_block_specs,
    xlstm_dims,
)


def _layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_segments, mlstm_per_segment, n_slstm)."""
    k = cfg.slstm_every
    assert cfg.n_layers % k == 0, "n_layers must divide slstm_every"
    segs = cfg.n_layers // k
    return segs, k - 1, segs


def param_specs(cfg: ArchConfig) -> dict:
    segs, per, n_s = _layout(cfg)
    D = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "embed": ParamSpec((cfg.vocab, D), ("tp", "fsdp"), dt),
        "ln_f": ParamSpec((D,), (None,), dt, init_scale=0.0),
        "unembed": ParamSpec((D, cfg.vocab), ("fsdp", "tp"), dt),
        "mlstm": mlstm_block_specs(cfg, segs * per),
        "slstm": slstm_block_specs(cfg, n_s),
    }


def init(cfg: ArchConfig, key) -> dict:
    return init_tree(key, param_specs(cfg))


def _embed(cfg, params, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    return jnp.take(params["embed"].astype(cdt), tokens, axis=0)


def forward(cfg, mesh, rules, params, tokens, *, remat=True, collect=False,
            plen=None):
    """``plen`` (traced scalar, slot-serving prefill only): tokens beyond
    position ``plen`` are right-padding of a length bucket.  Hidden states
    at positions ``< plen`` are untouched (the recurrence is causal); the
    *collected* states are forced to snapshot position ``plen`` exactly —
    each block treats padded steps as a cell identity and carries its conv
    state from the real prompt end (see xlstm.py)."""
    x = _embed(cfg, params, tokens)
    valid = None
    if plen is not None:
        valid = (jnp.arange(tokens.shape[1]) < plen)[None, :]
        x = jnp.where(valid[..., None], x, 0.0)  # pad activations stay finite
    x = constrain(x, rules, "dp", "sp", None)
    segs, per, _ = _layout(cfg)
    m_states, s_states = [], []
    for si in range(segs):
        if per:
            seg_bp = jax.tree.map(
                lambda p: p[si * per:(si + 1) * per], params["mlstm"]
            )

            def body(x, bp):
                x, st = mlstm_block_fwd(cfg, rules, x, bp, valid=valid,
                                        state_len=plen)
                return x, (st if collect else None)

            from .common import remat_wrap
            body = remat_wrap(body, remat)
            x, st = jax.lax.scan(body, x, seg_bp)
            m_states.append(st)
        sbp = jax.tree.map(lambda p: p[si], params["slstm"])
        x, sst = slstm_block_fwd(cfg, rules, x, sbp, valid=valid,
                                 state_len=plen)
        s_states.append(sst if collect else None)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if collect:
        mst = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *m_states) \
            if m_states else None
        sst = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *s_states)
        return x, (mst, sst)
    return x, None


def loss_fn(cfg, mesh, rules, params, batch, *, remat=True):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    hidden, _ = forward(cfg, mesh, rules, params, inp, remat=remat)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", hidden, params["unembed"].astype(cdt))
    logits = constrain(logits, rules, "dp", None, "tp")
    loss = cross_entropy_loss(logits, labels)
    return loss, {"ce_loss": loss, "lb_loss": jnp.float32(0.0),
                  "drop_frac": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving (stateful, cache = recurrent states; no KV)
# ---------------------------------------------------------------------------

# serve-engine state kind: every cache leaf is a per-lane recurrent state
# (O(1) in sequence length — nothing to page, nothing to prefix-share)
STATE_KIND = "recurrent"


def recurrent_leaf_axes(cfg: ArchConfig) -> dict:
    """Cache leaves that are per-lane *recurrent* state -> their lane axis.
    The serve engine zeroes these for inactive lanes (recurrent state is
    overwritten wholesale at admission, so unlike KV it can — and for
    numerical hygiene should — be hard-reset rather than lazily
    overwritten)."""
    return {
        name: 1
        for name in ("m_conv", "m_C", "m_n", "m_m",
                     "s_conv", "s_h", "s_c", "s_n", "s_m")
    }


def lane_leaf_axes(cfg: ArchConfig) -> dict:
    """All slot-cache leaves a lane owns (host-tier spill/restore unit).
    For a pure recurrence that is exactly the recurrent leaves."""
    return recurrent_leaf_axes(cfg)


def make_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """max_len is irrelevant for a recurrence — state is O(1) in seq."""
    segs, per, n_s = _layout(cfg)
    d_inner, dh_m, dh_s = xlstm_dims(cfg)
    H = cfg.n_heads
    nm = segs * per
    f32 = jnp.float32
    cdt = jnp.dtype(cfg.compute_dtype)
    return {
        "m_conv": jax.ShapeDtypeStruct((nm, batch, 3, d_inner), cdt),
        "m_C": jax.ShapeDtypeStruct((nm, batch, H, dh_m, dh_m), f32),
        "m_n": jax.ShapeDtypeStruct((nm, batch, H, dh_m), f32),
        "m_m": jax.ShapeDtypeStruct((nm, batch, H), f32),
        "s_conv": jax.ShapeDtypeStruct((n_s, batch, 3, cfg.d_model), cdt),
        "s_h": jax.ShapeDtypeStruct((n_s, batch, H, dh_s), f32),
        "s_c": jax.ShapeDtypeStruct((n_s, batch, H, dh_s), f32),
        "s_n": jax.ShapeDtypeStruct((n_s, batch, H, dh_s), f32),
        "s_m": jax.ShapeDtypeStruct((n_s, batch, H, dh_s), f32),
    }


def cache_pspec(cfg: ArchConfig, dec) -> dict:
    from jax.sharding import PartitionSpec as P
    b = dec.batch_axes or None
    tp = "model" if "model" in dec.mesh.axis_names else None
    return {
        "m_conv": P(None, b, None, tp),
        "m_C": P(None, b, None, None, tp),
        "m_n": P(None, b, None, None),
        "m_m": P(None, b, None),
        "s_conv": P(None, b, None, None),
        "s_h": P(None, b, None, None),
        "s_c": P(None, b, None, None),
        "s_n": P(None, b, None, None),
        "s_m": P(None, b, None, None),
    }


def _pack_cache(mst, sst):
    return {
        "m_conv": mst[0], "m_C": mst[1][0], "m_n": mst[1][1], "m_m": mst[1][2],
        "s_conv": sst[0], "s_h": sst[1][0], "s_c": sst[1][1],
        "s_n": sst[1][2], "s_m": sst[1][3],
    }


def prefill(cfg, mesh, rules, params, tokens, img_embeds=None, *, max_len=None):
    from .attention import DecodeSharding
    hidden, (mst, sst) = forward(
        cfg, mesh, rules, params, tokens, remat=False, collect=True
    )
    cache = _pack_cache(mst, sst)
    dec = DecodeSharding.choose(mesh, tokens.shape[0])
    specs = cache_pspec(cfg, dec)
    from .common import constrain_spec
    cache = {n: constrain_spec(c, mesh, specs[n]) for n, c in cache.items()}
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bd,dv->bv", hidden[:, -1], params["unembed"].astype(cdt))
    return cache, logits


def decode_step(cfg, mesh, rules, params, cache, tokens, cur_index):
    x = _embed(cfg, params, tokens[:, None])
    segs, per, _ = _layout(cfg)
    mc, sc = [], []
    for si in range(segs):
        if per:
            sl = slice(si * per, (si + 1) * per)
            seg_bp = jax.tree.map(lambda p: p[sl], params["mlstm"])

            def body(x, xs):
                bp, conv, C, n, m = xs
                x, (conv, cell) = mlstm_block_fwd(
                    cfg, rules, x, bp, conv_state=conv, cell_state=(C, n, m),
                    decode=True,
                )
                return x, (conv, cell[0], cell[1], cell[2])

            x, st = jax.lax.scan(
                body, x,
                (seg_bp, cache["m_conv"][sl], cache["m_C"][sl],
                 cache["m_n"][sl], cache["m_m"][sl]),
            )
            mc.append(st)
        sbp = jax.tree.map(lambda p: p[si], params["slstm"])
        x, (conv, cell) = slstm_block_fwd(
            cfg, rules, x, sbp,
            conv_state=cache["s_conv"][si],
            cell_state=(cache["s_h"][si], cache["s_c"][si],
                        cache["s_n"][si], cache["s_m"][si]),
            decode=True,
        )
        sc.append((conv,) + cell)
    x = rms_norm(x[:, 0], params["ln_f"], cfg.norm_eps)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(cdt))
    mcat = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *mc)
    scat = [jnp.stack([s[i] for s in sc]) for i in range(5)]
    new_cache = {
        "m_conv": mcat[0], "m_C": mcat[1], "m_n": mcat[2], "m_m": mcat[3],
        "s_conv": scat[0], "s_h": scat[1], "s_c": scat[2],
        "s_n": scat[3], "s_m": scat[4],
    }
    return logits, new_cache


def prefill_slot(cfg, mesh, rules, params, cache, tokens, slot, plen):
    """Prefill ONE prompt into lane ``slot`` of the slotted recurrent cache.

    tokens: (1, S_bucket) int32 right-padded to a length bucket; ``plen``
    (traced scalar) is the real prompt length and ``slot`` (traced scalar)
    the lane index.  Unlike a KV cache there is no position axis to make
    padding lazily inert — instead the forward *freezes every recurrence
    at position plen* (identity gates on padded steps, conv state sliced
    at plen; see xlstm.py), so the lane's written state is bitwise the
    exact-length prefill state.  Returns (cache', logits (1, V) at
    position plen - 1).
    """
    hidden, (mst, sst) = forward(
        cfg, mesh, rules, params, tokens, remat=False, collect=True,
        plen=plen,
    )
    new = _pack_cache(mst, sst)

    def write(c, n):
        start = (0, slot) + (0,) * (c.ndim - 2)
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), start)

    cache = {name: write(cache[name], new[name]) for name in cache}
    last = jax.lax.dynamic_index_in_dim(hidden, plen - 1, 1, keepdims=False)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bd,dv->bv", last, params["unembed"].astype(cdt))
    return cache, logits
