"""Zamba2 hybrid assembly [arXiv:2411.15242]: a stack of Mamba2 layers with
a single *shared* transformer block (attention + MLP) applied every
``attn_every`` layers, taking concat(hidden, original embedding) as input
(Zamba's global skip), projected back to d_model.

Simplifications vs the released checkpoints (noted in DESIGN.md): the
per-invocation LoRA deltas on the shared block are omitted; the shared
block's attention operates at d_model (after the concat projection) rather
than 2*d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .attention import DecodeSharding, chunked_attention, decode_attention, rope
from .common import (
    ParamSpec, ShardRules, constrain, cross_entropy_loss, decode_positions,
    init_tree, rms_norm,
)
from .ssm import (
    mamba_block_decode, mamba_block_fwd, mamba_block_specs, mamba_dims,
    mamba_state_specs,
)


def _segments(cfg: ArchConfig) -> list[int]:
    """Layer counts between shared-block invocations."""
    k = cfg.attn_every
    segs, rem = [], cfg.n_layers
    while rem > 0:
        segs.append(min(k, rem))
        rem -= k
    return segs


def n_shared_invocations(cfg: ArchConfig) -> int:
    return len(_segments(cfg))


def param_specs(cfg: ArchConfig) -> dict:
    D, dh, H, Hk = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv
    dt = jnp.dtype(cfg.param_dtype)
    shared = {
        "proj_in": ParamSpec((2 * D, D), ("fsdp", None), dt),
        "ln1": ParamSpec((D,), (None,), dt, init_scale=0.0),
        "wq": ParamSpec((D, H * dh), ("fsdp", "tp"), dt),
        "wk": ParamSpec((D, Hk * dh), ("fsdp", "tp"), dt),
        "wv": ParamSpec((D, Hk * dh), ("fsdp", "tp"), dt),
        "wo": ParamSpec((H * dh, D), ("tp", "fsdp"), dt),
        "ln2": ParamSpec((D,), (None,), dt, init_scale=0.0),
        "wg": ParamSpec((D, cfg.d_ff), ("fsdp", "tp"), dt),
        "wu": ParamSpec((D, cfg.d_ff), ("fsdp", "tp"), dt),
        "wd": ParamSpec((cfg.d_ff, D), ("tp", "fsdp"), dt),
    }
    return {
        "embed": ParamSpec((cfg.vocab, D), ("tp", "fsdp"), dt),
        "ln_f": ParamSpec((D,), (None,), dt, init_scale=0.0),
        "unembed": ParamSpec((D, cfg.vocab), ("fsdp", "tp"), dt),
        "mamba": mamba_block_specs(cfg, cfg.n_layers),
        "shared": shared,
    }


def init(cfg: ArchConfig, key) -> dict:
    return init_tree(key, param_specs(cfg))


# ---------------------------------------------------------------------------


def _shared_fwd(cfg, mesh, rules, x, x0, sp, *, collect_kv: bool):
    """Shared transformer block. x/x0: (B,S,D). Returns (x', (k,v)|None)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    u = jnp.concatenate([rms_norm(x, sp["ln1"], cfg.norm_eps), x0], axis=-1)
    u = jnp.einsum("bsd,dk->bsk", u, sp["proj_in"].astype(cdt))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q = jnp.einsum("bsd,dk->bsk", u, sp["wq"].astype(cdt)).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,dk->bsk", u, sp["wk"].astype(cdt)).reshape(B, S, Hk, dh)
    v = jnp.einsum("bsd,dk->bsk", u, sp["wv"].astype(cdt)).reshape(B, S, Hk, dh)
    q = rope(q, positions, cfg.rope_theta)
    kr = rope(k, positions, cfg.rope_theta)
    attn = chunked_attention(
        q, kr, v, causal=True,
        q_chunk=min(256, S), kv_chunk=min(256, S),
    )
    o = jnp.einsum("bsk,kd->bsd", attn.reshape(B, S, -1), sp["wo"].astype(cdt))
    x = constrain(x + o, rules, "dp", "sp", None)
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", h, sp["wg"].astype(cdt))
    uu = jnp.einsum("bsd,df->bsf", h, sp["wu"].astype(cdt))
    f = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * uu, sp["wd"].astype(cdt))
    x = constrain(x + f, rules, "dp", "sp", None)
    return x, ((kr, v) if collect_kv else None)


def _shared_decode(cfg, mesh, rules, x, x0, sp, kc, vc, cur_index, dec):
    cdt = jnp.dtype(cfg.compute_dtype)
    B, D = x.shape
    dh, H, Hk = cfg.head_dim, cfg.n_heads, cfg.n_kv
    u = jnp.concatenate([rms_norm(x, sp["ln1"], cfg.norm_eps), x0], axis=-1)
    u = jnp.einsum("bd,dk->bk", u, sp["proj_in"].astype(cdt))
    q = jnp.einsum("bd,dk->bk", u, sp["wq"].astype(cdt)).reshape(B, H, dh)
    k = jnp.einsum("bd,dk->bk", u, sp["wk"].astype(cdt)).reshape(B, Hk, dh)
    v = jnp.einsum("bd,dk->bk", u, sp["wv"].astype(cdt)).reshape(B, Hk, dh)
    # scalar (aligned batch) or (B,) vector (slotted serve: per-lane
    # positions) — decode_attention handles both
    pos = decode_positions(cur_index, B)
    q = rope(q[:, None], pos, cfg.rope_theta)[:, 0].reshape(B, Hk, H // Hk, dh)
    k = rope(k[:, None], pos, cfg.rope_theta)[:, 0]
    attn, kc, vc = decode_attention(q, kc, vc, k, v, cur_index, sharding=dec)
    o = jnp.einsum("bk,kd->bd", attn.reshape(B, H * dh), sp["wo"].astype(cdt))
    x = x + o
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    g = jnp.einsum("bd,df->bf", h, sp["wg"].astype(cdt))
    uu = jnp.einsum("bd,df->bf", h, sp["wu"].astype(cdt))
    f = jnp.einsum("bf,fd->bd", jax.nn.silu(g) * uu, sp["wd"].astype(cdt))
    return x + f, kc, vc


def _embed(cfg, params, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    return jnp.take(params["embed"].astype(cdt), tokens, axis=0)


def forward(cfg, mesh, rules, params, tokens, *, remat=True, collect=False,
            plen=None):
    """Returns (hidden, cache dict or None): with ``collect=True`` the
    second element is ``{"k", "v", "ssm", "conv"}`` — the shared block's
    stacked KV plus the mamba final states — else ``None``.

    ``plen`` (traced scalar, slot-serving prefill only): positions beyond
    it are right-padding of a length bucket.  The attention KV of padded
    positions is inert by causality (standard slotted-cache argument);
    the *mamba* states are forced to snapshot position ``plen`` exactly
    (``dt = 0`` identity steps + conv state sliced at plen, see ssm.py).
    """
    x = _embed(cfg, params, tokens)
    valid = None
    if plen is not None:
        valid = (jnp.arange(tokens.shape[1]) < plen)[None, :]
        x = jnp.where(valid[..., None], x, 0.0)  # pad activations stay finite
    x0 = x
    x = constrain(x, rules, "dp", "sp", None)
    segs = _segments(cfg)
    kvs, states = [], []
    off = 0
    for n in segs:
        x, kv = _shared_fwd(cfg, mesh, rules, x, x0, params["shared"], collect_kv=collect)
        kvs.append(kv)
        seg_bp = jax.tree.map(lambda p: p[off:off + n], params["mamba"])

        def body(x, bp):
            if collect:
                x, st = mamba_block_fwd(cfg, rules, x, bp, return_state=True,
                                        valid=valid, state_len=plen)
                return x, st
            return mamba_block_fwd(cfg, rules, x, bp), None

        from .common import remat_wrap
        body = remat_wrap(body, remat)
        x, st = jax.lax.scan(body, x, seg_bp)
        states.append(st)
        off += n
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if collect:
        ssm = jnp.concatenate([s[0] for s in states], axis=0)
        conv = jnp.concatenate([s[1] for s in states], axis=0)
        k = jnp.stack([kv[0] for kv in kvs])
        v = jnp.stack([kv[1] for kv in kvs])
        return x, {"k": k, "v": v, "ssm": ssm, "conv": conv}
    return x, None


def loss_fn(cfg, mesh, rules, params, batch, *, remat=True):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    hidden, _ = forward(cfg, mesh, rules, params, inp, remat=remat)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", hidden, params["unembed"].astype(cdt))
    logits = constrain(logits, rules, "dp", None, "tp")
    loss = cross_entropy_loss(logits, labels)
    return loss, {"ce_loss": loss, "lb_loss": jnp.float32(0.0),
                  "drop_frac": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

# serve-engine state kind: each lane carries BOTH a slotted KV segment
# (the shared attention block, seq axis, lazily-overwritten) and per-lane
# recurrent mamba leaves (no seq axis, hard-reset) — the engine composes
# the two through one cache dict
STATE_KIND = "hybrid"


def recurrent_leaf_axes(cfg: ArchConfig) -> dict:
    """The mamba leaves are per-lane recurrent state (lane axis 1); ``k``
    and ``v`` stay on the KV lifecycle (lazy overwrite)."""
    return {"ssm": 1, "conv": 1}


def lane_leaf_axes(cfg: ArchConfig) -> dict:
    """All slot-cache leaves a lane owns (host-tier spill/restore unit):
    the slotted KV segment (lane axis 1, after the shared-invocation
    axis) plus the recurrent mamba leaves."""
    return {"k": 1, "v": 1, **recurrent_leaf_axes(cfg)}


def make_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    ns = n_shared_invocations(cfg)
    ms = mamba_state_specs(cfg, cfg.n_layers, batch)
    kv = jax.ShapeDtypeStruct(
        (ns, batch, max_len, cfg.n_kv, cfg.head_dim), jnp.dtype(cfg.compute_dtype)
    )
    return {"k": kv, "v": kv, "ssm": ms["ssm"], "conv": ms["conv"]}


def cache_pspec(cfg: ArchConfig, dec: DecodeSharding):
    from jax.sharding import PartitionSpec as P
    b = dec.batch_axes or None
    s = dec.seq_axes or None
    return {
        "k": P(None, b, s, None, None),
        "v": P(None, b, s, None, None),
        "ssm": P(None, b, None, None, None),
        "conv": P(None, b, None, None),
    }


def prefill(cfg, mesh, rules, params, tokens, img_embeds=None, *, max_len=None):
    hidden, cache = forward(cfg, mesh, rules, params, tokens, remat=False, collect=True)
    dec = DecodeSharding.choose(mesh, tokens.shape[0])

    def pad(c):
        if max_len and max_len > c.shape[2]:
            pw = [(0, 0)] * c.ndim
            pw[2] = (0, max_len - c.shape[2])
            c = jnp.pad(c, pw)
        return c

    cache["k"], cache["v"] = pad(cache["k"]), pad(cache["v"])
    specs = cache_pspec(cfg, dec)
    from .common import constrain_spec
    cache = {n: constrain_spec(c, mesh, specs[n]) for n, c in cache.items()}
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bd,dv->bv", hidden[:, -1], params["unembed"].astype(cdt))
    return cache, logits


def decode_step(cfg, mesh, rules, params, cache, tokens, cur_index):
    x = _embed(cfg, params, tokens[:, None])[:, 0]
    x0 = x
    dec = DecodeSharding.choose(mesh, tokens.shape[0])
    segs = _segments(cfg)
    k_out, v_out, ssm_out, conv_out = [], [], [], []
    off = 0
    for si, n in enumerate(segs):
        x, kc, vc = _shared_decode(
            cfg, mesh, rules, x, x0, params["shared"],
            cache["k"][si], cache["v"][si], cur_index, dec,
        )
        k_out.append(kc); v_out.append(vc)
        seg_bp = jax.tree.map(lambda p: p[off:off + n], params["mamba"])
        seg_ssm = cache["ssm"][off:off + n]
        seg_conv = cache["conv"][off:off + n]

        def body(x, xs):
            bp, s_ssm, s_conv = xs
            x, s_ssm, s_conv = mamba_block_decode(cfg, rules, x, bp, s_ssm, s_conv)
            return x, (s_ssm, s_conv)

        x, (new_ssm, new_conv) = jax.lax.scan(body, x, (seg_bp, seg_ssm, seg_conv))
        ssm_out.append(new_ssm); conv_out.append(new_conv)
        off += n
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(cdt))
    new_cache = {
        "k": jnp.stack(k_out), "v": jnp.stack(v_out),
        "ssm": jnp.concatenate(ssm_out, axis=0),
        "conv": jnp.concatenate(conv_out, axis=0),
    }
    return logits, new_cache


def prefill_slot(cfg, mesh, rules, params, cache, tokens, slot, plen):
    """Prefill ONE prompt into lane ``slot`` of the composed hybrid cache.

    tokens: (1, S_bucket) right-padded; ``plen``/``slot`` traced scalars.
    The lane write covers both state kinds at once: the shared block's
    K/V land in the lane's seq slice ``[0, S_bucket)`` (padded tail inert
    by causality + lazy overwrite, exactly the lm slotted argument) and
    the mamba ``ssm``/``conv`` leaves land as the lane's O(1) recurrent
    snapshot at position ``plen`` (dt=0 identity padding, see ssm.py).
    Returns (cache', logits (1, V) at position plen - 1).
    """
    hidden, col = forward(
        cfg, mesh, rules, params, tokens, remat=False, collect=True,
        plen=plen,
    )

    def write(c, n):
        start = (0, slot) + (0,) * (c.ndim - 2)
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), start)

    cache = {name: write(cache[name], col[name]) for name in cache}
    last = jax.lax.dynamic_index_in_dim(hidden, plen - 1, 1, keepdims=False)
    cdt = jnp.dtype(cfg.compute_dtype)
    logits = jnp.einsum("bd,dv->bv", last, params["unembed"].astype(cdt))
    return cache, logits
