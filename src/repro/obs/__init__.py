"""Observability: metrics registry, structured tracing, flight recorder.

One :class:`Observer` handle is threaded through engine/router/train;
every emit helper is a guarded no-op when the corresponding component is
absent, so a disabled observer costs one ``is None`` check per site —
no host syncs, no executable-key changes (see docs/observability.md).
"""

from __future__ import annotations

import time

from .metrics import (Counter, Gauge, Histogram, MetricMap, MetricsRegistry,
                      merged_histogram)
from .recorder import FlightRecorder
from .trace import (NULL_SPAN, Tracer, load_jsonl, request_timeline,
                    to_chrome_trace, to_jsonl, validate)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricMap", "MetricsRegistry",
    "merged_histogram", "FlightRecorder", "Tracer", "NULL_SPAN",
    "load_jsonl", "request_timeline", "to_chrome_trace", "to_jsonl",
    "validate", "Observer",
]


class Observer:
    """Bundle of (metrics, tracer, recorder) with no-op emit helpers.

    ``metrics`` is always present (auto-created); ``tracer`` and
    ``recorder`` are optional.  ``child(name)`` hands a component (e.g.
    one router replica) its own metrics registry while sharing the
    tracer and recorder, so per-replica counters never collide but all
    events land on one timeline.
    """

    def __init__(self, *, metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 recorder: FlightRecorder | None = None,
                 name: str = "obs"):
        self.name = name
        self.metrics = metrics if metrics is not None else MetricsRegistry(name)
        self.tracer = tracer
        self.recorder = recorder
        if tracer is not None and recorder is not None and tracer._sink is None:
            tracer._sink = recorder.note

    @classmethod
    def full(cls, *, clock=time.perf_counter, capacity: int = 4096,
             dump_dir: str = ".", name: str = "obs") -> "Observer":
        """Everything on: metrics + tracer + recorder on one clock."""
        rec = FlightRecorder(capacity, clock=clock, dump_dir=dump_dir)
        return cls(tracer=Tracer(clock, sink=rec.note), recorder=rec, name=name)

    def child(self, name: str) -> "Observer":
        return Observer(metrics=MetricsRegistry(name), tracer=self.tracer,
                        recorder=self.recorder, name=name)

    # -- guarded emit helpers (no-ops without a tracer/recorder) --------

    def mark(self, phase: str, rid, **kw):
        if self.tracer is not None:
            self.tracer.mark(phase, rid, **kw)

    def instant(self, name: str, **kw):
        if self.tracer is not None:
            self.tracer.instant(name, **kw)

    def span(self, name: str, **kw):
        if self.tracer is not None:
            return self.tracer.span(name, **kw)
        return NULL_SPAN

    def begin(self, name: str, **kw):
        if self.tracer is not None:
            return self.tracer.begin(name, **kw)
        return None

    def end(self, sid, **kw):
        if self.tracer is not None and sid is not None:
            self.tracer.end(sid, **kw)

    def record(self, kind: str, **fields):
        if self.recorder is not None:
            self.recorder.record(kind, **fields)

    def dump(self, reason: str, *, context=None) -> str | None:
        if self.recorder is not None:
            return self.recorder.dump(reason, context=context)
        return None
