"""Typed metrics: Counter / Gauge / Histogram behind one registry.

Design constraints (see docs/observability.md):

- **Kinds never mix.**  A name registered as a counter can never be read
  or written as a gauge and vice versa; ``MetricsRegistry.check()`` and
  the engines' ``check_invariants`` assert this.
- **Mergeable percentiles.**  ``Histogram`` uses fixed log-scale buckets
  (growth ``2**(1/4)`` ≈ 1.19, so quantile answers carry ≤ ~9% relative
  error) shared by every instance, which makes ``merge`` a plain
  bucket-wise add — replica histograms fold into fleet histograms
  without resampling.
- **Cheap when idle.**  Metrics are plain python ints/floats on the
  host; nothing here touches a device buffer or forces a sync.
"""

from __future__ import annotations

import math
from collections.abc import MutableMapping

# Shared bucket layout: boundaries lo * GROWTH**i spanning [1e-9, ~1e9).
# All histograms use the same layout so merge() is bucket-wise addition.
_GROWTH = 2.0 ** 0.25
_LOG_GROWTH = math.log(_GROWTH)
_LO = 1e-9
_LOG_LO = math.log(_LO)
_NBUCKETS = int(math.ceil((math.log(1e9) - _LOG_LO) / _LOG_GROWTH)) + 1


def _bucket_index(value: float) -> int:
    """Bucket for a positive value; 0 holds (0, _LO], i holds lo*g**(i-1)..lo*g**i."""
    if value <= _LO:
        return 0
    i = int(math.floor((math.log(value) - _LOG_LO) / _LOG_GROWTH)) + 1
    return min(i, _NBUCKETS - 1)


def _bucket_mid(i: int) -> float:
    """Geometric midpoint of bucket i (representative value for quantiles)."""
    if i == 0:
        return _LO
    lo = _LO * _GROWTH ** (i - 1)
    return lo * math.sqrt(_GROWTH)


class Counter:
    """Monotone non-decreasing integer counter."""

    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: negative increment {n}")
        self.value += n

    def set(self, value: int) -> None:
        """Absolute set; must not decrease (used by restore/import paths)."""
        if value < self.value:
            raise ValueError(
                f"counter {self.name!r}: set({value}) would decrease from {self.value}")
        self.value = value


class Gauge:
    """Point-in-time value; supports absolute set and peak tracking."""

    kind = "gauge"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, value) -> None:
        self.value = value

    def set_max(self, value) -> None:
        if value > self.value:
            self.value = value


class Histogram:
    """Fixed-bucket log-scale histogram with mergeable quantiles.

    Buckets are sparse (dict index -> count); exact count/sum/min/max ride
    along so means are exact and quantiles clamp to the observed range.
    """

    kind = "histogram"
    __slots__ = ("name", "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v) or v < 0:
            raise ValueError(f"histogram {self.name!r}: bad observation {value!r}")
        i = _bucket_index(v)
        self.buckets[i] = self.buckets.get(i, 0) + 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (geometric bucket midpoint, clamped to
        the exact observed [min, max])."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * (self.count - 1)
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen > rank:
                return min(max(_bucket_mid(i), self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into self (same fixed layout ⇒ bucket-wise add)."""
        for i, n in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + n
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self


class MetricsRegistry:
    """Named, typed metric store.  Get-or-create per kind; a name can only
    ever hold one kind (TypeError otherwise)."""

    def __init__(self, name: str = "metrics"):
        self.name = name
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(
                f"registry {self.name!r}: metric {name!r} is a {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def kind(self, name: str) -> str | None:
        m = self._metrics.get(name)
        return None if m is None else m.kind

    def names(self):
        return sorted(self._metrics)

    def check(self) -> None:
        """Internal consistency: kind fields match classes, counters are
        non-negative, histogram bucket sums equal their counts."""
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                assert m.kind == "counter" and m.value >= 0, \
                    f"counter {name} corrupt: {m.value}"
            elif isinstance(m, Gauge):
                assert m.kind == "gauge", f"gauge {name} kind corrupt"
            elif isinstance(m, Histogram):
                assert m.kind == "histogram", f"histogram {name} kind corrupt"
                assert sum(m.buckets.values()) == m.count, \
                    f"histogram {name}: bucket sum != count"
            else:  # pragma: no cover - registry only creates the three kinds
                raise AssertionError(f"unknown metric type for {name}: {m!r}")

    def snapshot(self) -> dict:
        """Compact JSON-able dump of every metric."""
        out: dict[str, dict] = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, (Counter, Gauge)):
                out[name] = {"kind": m.kind, "value": m.value}
            else:
                out[name] = {
                    "kind": "histogram",
                    "count": m.count,
                    "sum": m.sum,
                    "min": None if m.count == 0 else m.min,
                    "max": None if m.count == 0 else m.max,
                    "p50": None if m.count == 0 else m.quantile(0.50),
                    "p90": None if m.count == 0 else m.quantile(0.90),
                    "p99": None if m.count == 0 else m.quantile(0.99),
                    "buckets": {str(i): n for i, n in sorted(m.buckets.items())},
                }
        return out


def merged_histogram(name: str, registries) -> Histogram:
    """Merge the histogram ``name`` across registries (missing ones skipped)."""
    out = Histogram(name)
    for reg in registries:
        if reg is not None and reg.kind(name) == "histogram":
            out.merge(reg.histogram(name))
    return out


class MetricMap(MutableMapping):
    """dict-shaped facade over a registry so legacy ``self.counters[...]``
    call sites keep working while values live in typed metrics.

    Keys listed in ``gauges`` are Gauge-backed (``map[k] = v`` is an
    absolute set); every other key is Counter-backed (``map[k] += 1``
    round-trips through ``__setitem__`` which enforces monotonicity).
    """

    def __init__(self, registry: MetricsRegistry, keys=(), gauges=(), prefix: str = ""):
        self._registry = registry
        gauges = frozenset(gauges)
        # key set is fixed at construction; metric objects are cached so
        # hot-path ``map[k] += 1`` is two dict probes, no registry walk
        self._objs: dict[str, object] = {}
        for k in keys:
            name = prefix + k
            self._objs[k] = registry.gauge(name) if k in gauges \
                else registry.counter(name)

    def __getitem__(self, key: str):
        return self._objs[key].value

    def __setitem__(self, key: str, value) -> None:
        self._objs[key].set(value)

    def __delitem__(self, key: str) -> None:  # pragma: no cover - unused
        raise TypeError("MetricMap keys are fixed")

    def __iter__(self):
        return iter(self._objs)

    def __len__(self) -> int:
        return len(self._objs)

    def __contains__(self, key) -> bool:
        return key in self._objs

    def copy(self) -> dict:
        return {k: m.value for k, m in self._objs.items()}
