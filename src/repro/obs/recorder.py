"""Flight recorder: bounded ring of recent events, dumped on failure.

The recorder passively mirrors every trace event (it is installed as the
tracer's sink) plus any explicitly ``record``-ed diagnostics.  When an
invariant trips — engine/router ``check_invariants``, a chaos-fuzzer
assertion, a CheckpointManager write failure — ``dump`` writes the ring
to disk as JSON so the moments *before* the failure are replayable.
"""

from __future__ import annotations

import json
import os
import re
import time
from collections import deque


class FlightRecorder:
    def __init__(self, capacity: int = 4096, *, clock=time.perf_counter,
                 dump_dir: str = "."):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self.dump_dir = dump_dir
        self._ring: deque = deque(maxlen=capacity)
        self.recorded = 0  # everything ever offered
        self.dumps = 0
        self.last_dump: str | None = None

    @property
    def dropped(self) -> int:
        return self.recorded - len(self._ring)

    def note(self, ev: dict) -> None:
        """Tracer sink: mirror a trace event into the ring."""
        self._ring.append({"seq": self.recorded, **ev})
        self.recorded += 1

    def record(self, kind: str, **fields) -> None:
        """Record a non-trace diagnostic event."""
        self.note({"ph": "i", "name": kind, "cat": "recorder",
                   "ts": self.clock(), "track": "recorder", "args": fields})

    def events(self) -> list[dict]:
        return list(self._ring)

    def _next_index(self) -> int:
        """Next free ``flightrec_NNN.json`` index in ``dump_dir``.

        Scanned from the directory, not a per-recorder counter: several
        recorders (or several processes) sharing a dump_dir would each
        start their counter at 0 and silently overwrite each other's
        dump 000 — the one artifact written specifically because
        something just went wrong."""
        best = -1
        for name in os.listdir(self.dump_dir):
            m = re.match(r"flightrec_(\d+)\.json$", name)
            if m:
                best = max(best, int(m.group(1)))
        return best + 1

    def dump(self, reason: str, *, context=None, path: str | None = None) -> str:
        """Write the ring to disk; returns the path written."""
        if path is None:
            os.makedirs(self.dump_dir, exist_ok=True)
            path = os.path.join(
                self.dump_dir, f"flightrec_{self._next_index():03d}.json")
        doc = {
            "reason": reason,
            "context": context,
            "ts": self.clock(),
            "recorded": self.recorded,
            "dropped": self.dropped,
            "events": self.events(),
        }
        with open(path, "w") as f:
            json.dump(doc, f, default=str)
        self.dumps += 1
        self.last_dump = path
        return path
