"""Structured tracing: per-request timelines and phase spans.

Every event is a plain dict; the tracer stamps it with the *injectable*
clock it was constructed with, so traces recorded under the fuzzer's
fake clock are bit-for-bit deterministic.  Two export formats:

- JSONL (one event per line) — the durable artifact, schema-validated
  by :func:`validate`.
- Chrome trace / Perfetto JSON — ``to_chrome_trace`` maps tracks to
  tids and request ids to per-request tracks; load the file at
  https://ui.perfetto.dev or chrome://tracing.

Event schema (all events):
  ``ph``    "B" (span begin) | "E" (span end) | "i" (instant)
  ``name``  span/event name ("decode", "prefill_chunk", "submit", ...)
  ``cat``   category: "engine", "router", "train", "aot", "request"
  ``ts``    clock seconds (float, from the injected clock)
  ``track`` logical thread ("engine", "replica0", "train", ...)
  ``rid``   request id (request-lifecycle events only, else absent)
  ``sid``   span id (B/E pairs share one; instants have none)
  ``args``  free-form JSON-able payload

Request lifecycle phases (``cat == "request"``, ``ph == "i"``) follow
the taxonomy in docs/observability.md: submit → queue/route → admit →
prefill_chunk* → first_token → decode* → (preempt | retry | replay |
failover | drain | migrate)* → terminal.  ``validate`` enforces that a
request's first event is ``submit`` and its ``terminal`` event (if any)
is last.
"""

from __future__ import annotations

import json
import time

TERMINAL = "terminal"
SUBMIT = "submit"


class _NullSpan:
    """Reusable no-op context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_sid")

    def __init__(self, tracer, sid):
        self._tracer = tracer
        self._sid = sid

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer.end(self._sid)
        return False


class Tracer:
    """Append-only event collector bound to one clock."""

    def __init__(self, clock=time.perf_counter, sink=None):
        self.clock = clock
        self.events: list[dict] = []
        self._sink = sink
        self._next_sid = 0
        self._open: dict[int, dict] = {}

    def _emit(self, ev: dict) -> dict:
        self.events.append(ev)
        if self._sink is not None:
            self._sink(ev)
        return ev

    def begin(self, name: str, *, cat: str = "engine", track: str = "engine",
              rid=None, **args) -> int:
        sid = self._next_sid
        self._next_sid += 1
        ev = {"ph": "B", "name": name, "cat": cat, "ts": self.clock(),
              "track": track, "sid": sid}
        if rid is not None:
            ev["rid"] = rid
        if args:
            ev["args"] = args
        self._open[sid] = ev
        self._emit(ev)
        return sid

    def end(self, sid: int, **args) -> None:
        opened = self._open.pop(sid)
        ev = {"ph": "E", "name": opened["name"], "cat": opened["cat"],
              "ts": self.clock(), "track": opened["track"], "sid": sid}
        if "rid" in opened:
            ev["rid"] = opened["rid"]
        if args:
            ev["args"] = args
        self._emit(ev)

    def span(self, name: str, **kw) -> _Span:
        return _Span(self, self.begin(name, **kw))

    def instant(self, name: str, *, cat: str = "engine", track: str = "engine",
                rid=None, **args) -> dict:
        ev = {"ph": "i", "name": name, "cat": cat, "ts": self.clock(),
              "track": track}
        if rid is not None:
            ev["rid"] = rid
        if args:
            ev["args"] = args
        return self._emit(ev)

    def mark(self, phase: str, rid, *, track: str = "engine", **args) -> dict:
        """Request-lifecycle instant (cat='request')."""
        return self.instant(phase, cat="request", track=track, rid=rid, **args)


def to_jsonl(events, path: str) -> str:
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    return path


def load_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def to_chrome_trace(events, path: str | None = None) -> dict:
    """Convert to Chrome trace ("traceEvents") JSON.  Phase spans land on
    one tid per logical track; request-lifecycle instants land on a
    per-request tid (1000 + rid) so Perfetto shows one row per request."""
    tracks: dict[str, int] = {}
    out = []

    def tid_for(ev):
        if ev.get("cat") == "request" and "rid" in ev:
            return 1000 + int(ev["rid"])
        track = ev.get("track", "main")
        if track not in tracks:
            tracks[track] = len(tracks) + 1
        return tracks[track]

    for ev in events:
        row = {
            "name": ev["name"],
            "cat": ev.get("cat", "misc"),
            "ph": ev["ph"],
            "ts": ev["ts"] * 1e6,  # chrome trace wants microseconds
            "pid": 1,
            "tid": tid_for(ev),
        }
        if ev["ph"] == "i":
            row["s"] = "t"  # thread-scoped instant
        args = dict(ev.get("args", ()))
        if "rid" in ev:
            args["rid"] = ev["rid"]
        if args:
            row["args"] = args
        out.append(row)

    meta = [{"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
             "args": {"name": track}} for track, tid in tracks.items()]
    rids = sorted({ev["rid"] for ev in events
                   if ev.get("cat") == "request" and "rid" in ev})
    meta += [{"name": "thread_name", "ph": "M", "pid": 1, "tid": 1000 + rid,
              "args": {"name": f"request {rid}"}} for rid in rids]
    doc = {"traceEvents": meta + out, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc


def request_timeline(events, rid) -> list[dict]:
    """All lifecycle events for one request, in emission order."""
    return [ev for ev in events
            if ev.get("rid") == rid and ev.get("cat") == "request"]


def validate(events) -> dict:
    """Schema validation.  Raises AssertionError on the first violation;
    returns summary stats ({'events', 'spans', 'requests', 'terminals'}).

    Checks:
      - every event has ph/name/cat/ts/track and a known ph
      - timestamps are globally non-decreasing
      - B/E spans balance LIFO per track, with non-negative duration,
        and no span is left open
      - per request id: first lifecycle event is 'submit'; at most one
        'terminal' and nothing follows it
    """
    open_stacks: dict[str, list[dict]] = {}
    last_ts = None
    spans = 0
    seen_rid: dict[object, str] = {}  # rid -> last phase
    terminals = 0
    for i, ev in enumerate(events):
        for field in ("ph", "name", "cat", "ts", "track"):
            assert field in ev, f"event {i} missing {field!r}: {ev}"
        assert ev["ph"] in ("B", "E", "i"), f"event {i}: bad ph {ev['ph']!r}"
        if last_ts is not None:
            assert ev["ts"] >= last_ts, \
                f"event {i} ({ev['name']}): ts went backwards " \
                f"({ev['ts']} < {last_ts})"
        last_ts = ev["ts"]
        stack = open_stacks.setdefault(ev["track"], [])
        if ev["ph"] == "B":
            stack.append(ev)
        elif ev["ph"] == "E":
            assert stack, f"event {i}: E {ev['name']!r} with no open span " \
                          f"on track {ev['track']!r}"
            opened = stack.pop()
            assert opened["name"] == ev["name"] and \
                opened.get("sid") == ev.get("sid"), \
                f"event {i}: E {ev['name']!r}/sid={ev.get('sid')} does not " \
                f"match open B {opened['name']!r}/sid={opened.get('sid')}"
            assert ev["ts"] >= opened["ts"], \
                f"event {i}: span {ev['name']!r} has negative duration"
            spans += 1
        if ev.get("cat") == "request" and "rid" in ev:
            rid = ev["rid"]
            if rid not in seen_rid:
                assert ev["name"] == SUBMIT, \
                    f"request {rid}: first lifecycle event is " \
                    f"{ev['name']!r}, expected '{SUBMIT}'"
            else:
                assert seen_rid[rid] != TERMINAL, \
                    f"request {rid}: event {ev['name']!r} after terminal"
            seen_rid[rid] = TERMINAL if ev["name"] == TERMINAL else ev["name"]
            if ev["name"] == TERMINAL:
                terminals += 1
    for track, stack in open_stacks.items():
        assert not stack, \
            f"track {track!r}: {len(stack)} unbalanced open span(s), " \
            f"first: {stack[0]['name']!r}"
    return {"events": len(events), "spans": spans,
            "requests": len(seen_rid), "terminals": terminals}
