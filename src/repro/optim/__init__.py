from .flat import FlatLayout, flat_adam_update, flatten, make_layout, unflatten
from .rules import (
    OptConfig, apply_update, clip_by_global_norm, global_norm, init_state,
    state_pspecs,
)

__all__ = [
    "FlatLayout", "flat_adam_update", "flatten", "make_layout", "unflatten",
    "OptConfig", "apply_update", "clip_by_global_norm", "global_norm",
    "init_state", "state_pspecs",
]
