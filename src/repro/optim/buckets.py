"""Bucketed gradient-collective engine over the flat buffer (paper §3.3 +
comm/compute overlap).

``optim/flat.py`` gives ONE monolithic flat buffer and therefore ONE giant
all-reduce that serializes the entire communication volume behind the end
of the backward pass.  This module partitions the :class:`FlatLayout` into
fixed-byte **buckets** (default ~4 MiB, boundaries aligned to parameter
boundaries so a tensor never straddles two collectives) and reduces each
bucket independently.  Because the buckets are independent ops in the
lowered program, XLA's latency-hiding scheduler can start reducing early
buckets while later gradient math is still in flight — the same lever
Theano-MPI and ChainerMN identify as the difference between linear and
sub-linear data-parallel scaling.

Two reduction programs, both meant to run *inside* ``shard_map`` over the
data-parallel axes:

* ``bucketed_all_reduce``   — faithful mode: one ``pmean``/``psum`` per
  bucket; every worker ends with the full reduced flat gradient (the
  paper's Appendix-A program, bucketed).
* ``bucketed_reduce_scatter`` / ``bucketed_all_gather`` — ZeRO mode: each
  bucket is reduce-scattered so each worker owns ``1/N`` of it, the fused
  flat-Adam update runs on the owned shard only (sharded optimizer
  state), and the updated parameter shard is all-gathered back.

The scattered layout is *bucket-major*: worker ``w`` owns piece ``w`` of
every bucket, concatenated in bucket order.  Buckets are padded (by at
most ``n_shards - 1`` elements) so each piece is equal-sized; treat
scattered buffers as opaque between ``bucketed_reduce_scatter`` and
``bucketed_all_gather``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from .flat import FlatLayout, flat_adam_update

DEFAULT_BUCKET_BYTES = 4 << 20  # ~4 MiB, the NCCL-era sweet spot

# Autotuner target: the fixed per-collective latency may eat at most this
# fraction of each bucket's total collective time.  Smaller fraction ->
# bigger buckets (less overlap granularity), larger -> more launch tax.
_AUTO_LATENCY_FRACTION = 0.05
_AUTO_MIN_BYTES = 1 << 20
_AUTO_MAX_BYTES = 64 << 20


def resolve_bucket_bytes(bucket_mb, *, group_size: int = 1) -> int:
    """Resolve ``OptConfig.bucket_mb`` (a float MiB or ``"auto"``) to bytes.

    ``"auto"`` sizes buckets from the roofline model: an all-reduce over a
    ring of ``group_size`` workers moves ``2(g-1)/g * b`` wire bytes and
    pays a fixed per-collective latency ``ICI_LATENCY_S``; the smallest
    bucket whose wire time keeps that latency under
    ``_AUTO_LATENCY_FRACTION`` of the total maximizes overlap granularity
    without drowning in launch tax.  When the roofline lacks interconnect
    numbers (``ICI_BW``/``ICI_LATENCY_S`` unset), auto falls back to the
    static ~4 MiB default.
    """
    if bucket_mb != "auto":
        return int(float(bucket_mb) * (1 << 20))
    from repro.roofline import analysis

    bw = getattr(analysis, "ICI_BW", None)
    lat = getattr(analysis, "ICI_LATENCY_S", None)
    if not bw or not lat:
        return DEFAULT_BUCKET_BYTES
    g = max(int(group_size), 2)   # wire factor of a degenerate group ~ g=2
    wire_factor = 2.0 * (g - 1) / g
    # lat <= f * (lat + wire_factor*b/bw)  =>  b >= lat*(1-f)/f * bw/wire_factor
    f = _AUTO_LATENCY_FRACTION
    b = lat * (1.0 - f) / f * bw / wire_factor
    return int(min(max(b, _AUTO_MIN_BYTES), _AUTO_MAX_BYTES))


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """A partition of ``[0, total)`` of a FlatLayout into buckets.

    ``starts[i] + sizes[i] == starts[i+1]`` and the buckets cover the
    buffer exactly.  ``padded[i]`` is ``sizes[i]`` rounded up to a multiple
    of ``n_shards`` (used only by the scatter path).
    """

    starts: tuple[int, ...]
    sizes: tuple[int, ...]
    padded: tuple[int, ...]
    n_shards: int
    bucket_bytes: int

    @property
    def num_buckets(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        return (self.starts[-1] + self.sizes[-1]) if self.sizes else 0

    @property
    def scattered_total(self) -> int:
        """Global length of a scattered (bucket-major, per-bucket padded)
        buffer: sum of padded bucket sizes."""
        return sum(self.padded)

    @property
    def local_total(self) -> int:
        """Per-worker length of a scattered buffer."""
        return self.scattered_total // self.n_shards


def make_buckets(
    layout: FlatLayout,
    *,
    bucket_bytes: int = DEFAULT_BUCKET_BYTES,
    itemsize: int = 4,
    n_shards: int = 1,
) -> BucketLayout:
    """Greedy partition at parameter boundaries.

    Walks the layout's parameter segments in offset order, closing a bucket
    once it reaches ``bucket_bytes`` worth of elements.  A single parameter
    larger than the target gets a bucket of its own (never split).  The
    alignment tail of the flat buffer (``layout.total - layout.unpadded``)
    rides in the last bucket.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    target = max(1, bucket_bytes // itemsize)

    starts: list[int] = []
    sizes: list[int] = []
    acc = 0  # elements accumulated in the open bucket
    for off, size in zip(layout.offsets, layout.sizes):
        if acc == 0:
            starts.append(off)
        acc += size
        if acc >= target:
            sizes.append(acc)
            acc = 0
    if acc:
        sizes.append(acc)
    tail = layout.total - layout.unpadded
    if tail:
        if sizes:
            sizes[-1] += tail
        else:
            starts.append(0)
            sizes.append(layout.total)
    padded = tuple(-(-s // n_shards) * n_shards for s in sizes)
    return BucketLayout(
        starts=tuple(starts), sizes=tuple(sizes), padded=padded,
        n_shards=n_shards, bucket_bytes=bucket_bytes,
    )


def _slices(buf: jnp.ndarray, buckets: BucketLayout):
    return [
        jax.lax.slice_in_dim(buf, s, s + z, axis=0)
        for s, z in zip(buckets.starts, buckets.sizes)
    ]


# ---------------------------------------------------------------------------
# Faithful mode: per-bucket all-reduce
# ---------------------------------------------------------------------------


def bucketed_all_reduce(buf, buckets: BucketLayout, axes, op: str = "mean"):
    """Reduce ``buf`` across ``axes`` one bucket at a time (inside shard_map).

    Numerically identical to a monolithic ``pmean``/``psum`` of the whole
    buffer (same per-element addition order); structurally it emits
    ``num_buckets`` independent collectives that the scheduler can overlap
    with whatever computation still feeds later buckets.
    """
    red = jax.lax.pmean if op == "mean" else jax.lax.psum
    parts = [red(p, axes) for p in _slices(buf, buckets)]
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


# ---------------------------------------------------------------------------
# ZeRO mode: per-bucket reduce-scatter / all-gather
# ---------------------------------------------------------------------------


def bucketed_reduce_scatter(buf, buckets: BucketLayout, axes, op: str = "mean"):
    """Reduce-scatter ``buf`` per bucket: returns the worker's scattered
    shard, length ``buckets.local_total`` (bucket-major layout)."""
    n = buckets.n_shards
    pieces = []
    for part, size, pad_to in zip(_slices(buf, buckets), buckets.sizes, buckets.padded):
        if pad_to != size:
            part = jnp.concatenate([part, jnp.zeros((pad_to - size,), part.dtype)])
        piece = compat.psum_scatter(part, axes, tiled=True)
        if op == "mean":
            piece = piece / n
        pieces.append(piece)
    return jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]


def bucketed_all_gather(local, buckets: BucketLayout, axes):
    """Inverse of :func:`bucketed_reduce_scatter`'s layout: gather each
    bucket's pieces and reassemble the full flat buffer (length
    ``buckets.total``), dropping the per-bucket padding."""
    n = buckets.n_shards
    parts = []
    off = 0
    for size, pad_to in zip(buckets.sizes, buckets.padded):
        k = pad_to // n
        piece = jax.lax.slice_in_dim(local, off, off + k, axis=0)
        off += k
        full = jax.lax.all_gather(piece, axes, axis=0, tiled=True)
        if pad_to != size:
            full = jax.lax.slice_in_dim(full, 0, size, axis=0)
        parts.append(full)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def scatter_flat(buf, buckets: BucketLayout, index):
    """Worker ``index``'s scattered shard of a replicated flat buffer —
    what :func:`bucketed_reduce_scatter` would hand that worker if every
    worker contributed ``buf / n`` (used to seed/inspect scattered state).

    ``index`` may be a traced scalar (e.g. ``lax.axis_index``).
    """
    n = buckets.n_shards
    pieces = []
    for start, size, pad_to in zip(buckets.starts, buckets.sizes, buckets.padded):
        k = pad_to // n
        part = jax.lax.dynamic_slice_in_dim(
            jnp.concatenate([
                jax.lax.slice_in_dim(buf, start, start + size, axis=0),
                jnp.zeros((pad_to - size,), buf.dtype),
            ]) if pad_to != size else jax.lax.slice_in_dim(buf, start, start + size, axis=0),
            index * k, k, axis=0,
        )
        pieces.append(part)
    return jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]


# ---------------------------------------------------------------------------
# Elastic restore: host-side reshard of scattered buffers across dp sizes
# ---------------------------------------------------------------------------
# A checkpointed ZeRO m/v buffer is the GLOBAL scattered array: worker-
# major segments (each ``local_total`` long), every segment bucket-major
# with piece ``w`` of each padded bucket.  That layout bakes in ``(bucket
# boundaries, n_shards)``, so restoring a dp=8 checkpoint onto dp=4 must
# first undo the old scatter and re-apply the new one.  Pure host-numpy
# data movement — bitwise, no arithmetic.


def unscatter_flat(buf, buckets: BucketLayout) -> np.ndarray:
    """Global scattered buffer -> the canonical flat buffer (length
    ``buckets.total``), dropping per-bucket padding."""
    buf = np.asarray(buf)
    if buf.shape != (buckets.scattered_total,):
        raise ValueError(
            f"scattered buffer has shape {buf.shape}, layout wants "
            f"({buckets.scattered_total},)")
    n = buckets.n_shards
    workers = buf.reshape(n, buckets.local_total)
    parts, off = [], 0
    for size, pad_to in zip(buckets.sizes, buckets.padded):
        k = pad_to // n
        # worker-major concat of each worker's piece == the padded bucket
        parts.append(workers[:, off: off + k].reshape(-1)[:size])
        off += k
    return np.concatenate(parts) if parts else buf[:0]


def rescatter_flat(flat, buckets: BucketLayout) -> np.ndarray:
    """Canonical flat buffer -> the global scattered buffer (length
    ``buckets.scattered_total``), zero-filling per-bucket padding —
    the host inverse of :func:`unscatter_flat`."""
    flat = np.asarray(flat)
    if flat.shape != (buckets.total,):
        raise ValueError(
            f"flat buffer has shape {flat.shape}, layout wants "
            f"({buckets.total},)")
    n = buckets.n_shards
    segs: list[list[np.ndarray]] = [[] for _ in range(n)]
    for start, size, pad_to in zip(buckets.starts, buckets.sizes, buckets.padded):
        part = flat[start: start + size]
        if pad_to != size:
            part = np.concatenate(
                [part, np.zeros(pad_to - size, flat.dtype)])
        k = pad_to // n
        for w in range(n):
            segs[w].append(part[w * k: (w + 1) * k])
    if not segs[0]:
        return flat[:0]
    return np.concatenate([np.concatenate(s) for s in segs])


def reshard_scattered(buf, old: BucketLayout, new: BucketLayout) -> np.ndarray:
    """Re-lay a scattered buffer saved under ``old`` (its dp size and
    bucket boundaries) for a job running under ``new``.  Adam's moment
    padding lanes are identically zero (their gradient is always the
    scatter pad), so dropping and re-zero-filling them is bitwise."""
    if old.total != new.total:
        raise ValueError(
            f"bucket layouts cover different flat buffers: "
            f"{old.total} vs {new.total} elements")
    return rescatter_flat(unscatter_flat(buf, old), new)


# ---------------------------------------------------------------------------
# Fused flat-Adam dispatch (Pallas kernel on TPU, jnp reference elsewhere)
# ---------------------------------------------------------------------------


def flat_adam_apply(p, g, m, v, step, *, lr, beta1, beta2, eps,
                    weight_decay: float = 0.0, use_kernel: bool | None = None):
    """One fused elementwise Adam pass over flat fp32 buffers.

    ``use_kernel=None`` picks the Pallas ``kernels/flat_adam`` kernel on
    TPU and the pure-jnp reference elsewhere (the kernel's interpret mode
    is correct but slow off-TPU).  Returns ``(p', m', v')``.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.flat_adam.kernel import flat_adam

        return flat_adam(
            p, g, m, v, jnp.reshape(step, (1,)).astype(jnp.int32),
            lr=lr, beta1=beta1, beta2=beta2, eps=eps, weight_decay=weight_decay,
        )
    p_new, m_new, v_new = flat_adam_update(
        p, g, m, v, step, lr=lr, beta1=beta1, beta2=beta2, eps=eps
    )
    if weight_decay:
        p_new = p_new - lr * weight_decay * p
    return p_new, m_new, v_new
