"""Flattened-parameter buffers (paper §3.3).

"These store the gradients of all variables into one (flattened) array for
faster inter-GPU communication": a single contiguous fp32 buffer means the
gradient all-reduce is ONE collective instead of one per parameter, and the
optimizer update is one fused elementwise pass (see kernels/flat_adam for
the Pallas version).  The buffer is padded to a multiple of ``align`` so it
shards evenly over any mesh axis (ZeRO over fsdp).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    offsets: tuple[int, ...]
    sizes: tuple[int, ...]
    total: int                      # padded length

    @property
    def unpadded(self) -> int:
        return self.offsets[-1] + self.sizes[-1] if self.sizes else 0


def make_layout(tree, align: int = 512) -> FlatLayout:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    total = int(np.ceil(off / align) * align) if off else align
    return FlatLayout(treedef, shapes, dtypes, tuple(offsets), sizes, total)


def flatten(layout: FlatLayout, tree, dtype=jnp.float32) -> jnp.ndarray:
    leaves = jax.tree.flatten(tree)[0]
    parts = [l.astype(dtype).reshape(-1) for l in leaves]
    pad = layout.total - layout.unpadded
    if pad:
        parts.append(jnp.zeros((pad,), dtype))
    return jnp.concatenate(parts) if parts else jnp.zeros((layout.total,), dtype)


def unflatten(layout: FlatLayout, buf: jnp.ndarray, dtype=None):
    """Rebuild the tree from a flat buffer.  ``dtype`` overrides the
    per-leaf cast (e.g. keep fp32 optimizer state flat alongside bf16
    parameters sharing one layout)."""
    leaves = []
    for off, size, shape, dt in zip(
        layout.offsets, layout.sizes, layout.shapes, layout.dtypes
    ):
        leaves.append(
            jax.lax.dynamic_slice_in_dim(buf, off, size)
            .reshape(shape).astype(dt if dtype is None else dtype)
        )
    return jax.tree.unflatten(layout.treedef, leaves)


# ---------------------------------------------------------------------------
# Flat Adam (reference; the Pallas kernel in kernels/flat_adam fuses this)
# ---------------------------------------------------------------------------


def flat_adam_update(p, g, m, v, step, *, lr, beta1=0.9, beta2=0.95, eps=1e-8):
    """One fused elementwise pass over the flat buffers (all fp32 1-D)."""
    m = beta1 * m + (1 - beta1) * g
    v = beta2 * v + (1 - beta2) * g * g
    t = step.astype(jnp.float32)
    mhat = m / (1 - beta1 ** t)
    vhat = v / (1 - beta2 ** t)
    p = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p, m, v
