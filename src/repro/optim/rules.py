"""Update rules (paper §3.3: the Lasagne rules adapted to multi-device —
SGD, Nesterov momentum, RMSProp, Adam) as pure pytree transforms.

States are fp32 regardless of parameter dtype (mixed-precision training);
with FSDP rules the states inherit the parameter shardings, which is
ZeRO-style optimizer-state sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

KINDS = ("sgd", "momentum", "rmsprop", "adam", "adamw")


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adam"
    lr: float = 3e-4
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # scan the update over each stacked leaf's layer axis: bounds the live
    # f32 temporaries of the elementwise update chain to one layer's worth
    # (the jnp mirror of the fused kernels/flat_adam pass; see §Perf)
    chunked: bool = False
    # flat-gradient bucket size (MiB) for the bucketed collective engine
    # (optim/buckets.py); parameter-boundary-aligned greedy partition.
    # "auto" sizes buckets from the roofline interconnect model
    # (optim/buckets.resolve_bucket_bytes), falling back to 4 MiB when the
    # roofline lacks interconnect numbers.
    bucket_mb: float | str = 4.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind {self.kind!r} not in {KINDS}")
        if isinstance(self.bucket_mb, str):
            if self.bucket_mb != "auto":
                raise ValueError(
                    f"bucket_mb must be a float (MiB) or 'auto', "
                    f"got {self.bucket_mb!r}"
                )
        elif self.bucket_mb <= 0:
            raise ValueError(f"bucket_mb must be positive, got {self.bucket_mb}")


def init_state(cfg: OptConfig, params) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    st: dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "momentum":
        st["m"] = zeros()
    elif cfg.kind == "rmsprop":
        st["v"] = zeros()
    elif cfg.kind in ("adam", "adamw"):
        st["m"] = zeros()
        st["v"] = zeros()
    return st


def state_pspecs(cfg: OptConfig, param_pspecs) -> dict:
    """Optimizer-state shardings mirror the parameter shardings (ZeRO)."""
    from jax.sharding import PartitionSpec as P
    st: dict[str, Any] = {"step": P()}
    if cfg.kind == "momentum":
        st["m"] = param_pspecs
    elif cfg.kind == "rmsprop":
        st["v"] = param_pspecs
    elif cfg.kind in ("adam", "adamw"):
        st["m"] = param_pspecs
        st["v"] = param_pspecs
    return st


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_update(cfg: OptConfig, params, grads, state) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        metrics["grad_norm"] = gnorm
    step = state["step"] + 1
    lr = jnp.float32(cfg.lr)
    new_state: dict[str, Any] = {"step": step}

    def f32(x):
        return x.astype(jnp.float32)

    if cfg.kind == "sgd":
        upd = jax.tree.map(lambda g: lr * f32(g), grads)
    elif cfg.kind == "momentum":
        m = jax.tree.map(lambda m, g: cfg.momentum * m + f32(g), state["m"], grads)
        # Nesterov
        upd = jax.tree.map(lambda m, g: lr * (cfg.momentum * m + f32(g)), m, grads)
        new_state["m"] = m
    elif cfg.kind == "rmsprop":
        v = jax.tree.map(
            lambda v, g: cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(f32(g)),
            state["v"], grads,
        )
        upd = jax.tree.map(lambda v, g: lr * f32(g) / (jnp.sqrt(v) + cfg.eps), v, grads)
        new_state["v"] = v
    elif cfg.chunked:  # adam/adamw, layer-scanned (bounded f32 temporaries)
        bc1 = 1 - cfg.beta1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.beta2 ** step.astype(jnp.float32)
        wd = cfg.weight_decay if cfg.kind == "adamw" else 0.0

        def leaf_update(p, g, m, v):
            def one(p, g, m, v):
                g = g.astype(jnp.float32)
                m = cfg.beta1 * m + (1 - cfg.beta1) * g
                v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
                u = lr * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
                if wd:
                    u = u + lr * wd * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - u).astype(p.dtype), m, v

            if p.ndim >= 2 and p.shape[0] > 1:
                # fori_loop over the (unsharded) stacked-layer axis with
                # in-place dynamic updates on the loop carry: bounds the
                # live f32 temps to one layer's slice WITHOUT the ys
                # double-buffer a scan would allocate — the jnp mirror of
                # the fused kernels/flat_adam pass
                def body(i, carry):
                    pc, mc, vc = carry
                    sl = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
                    pn, mn, vn = one(sl(pc), sl(g), sl(mc), sl(vc))
                    up = lambda a, x: jax.lax.dynamic_update_index_in_dim(a, x, i, 0)
                    return up(pc, pn), up(mc, mn), up(vc, vn)

                return jax.lax.fori_loop(0, p.shape[0], body, (p, m, v))
            return one(p, g, m, v)

        out = jax.tree.map(leaf_update, params, grads, state["m"], state["v"])
        flat, _ = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        ptree = jax.tree.structure(params)
        new_params = jax.tree.unflatten(ptree, [o[0] for o in flat])
        new_state["m"] = jax.tree.unflatten(ptree, [o[1] for o in flat])
        new_state["v"] = jax.tree.unflatten(ptree, [o[2] for o in flat])
        return new_params, new_state, metrics
    else:  # adam / adamw
        m = jax.tree.map(lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * f32(g),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v, g: cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(f32(g)),
            state["v"], grads,
        )
        bc1 = 1 - cfg.beta1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.beta2 ** step.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: lr * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps), m, v
        )
        new_state["m"], new_state["v"] = m, v

    if cfg.kind == "adamw" and cfg.weight_decay:
        upd = jax.tree.map(
            lambda u, p: u + lr * cfg.weight_decay * f32(p), upd, params
        )
    new_params = jax.tree.map(lambda p, u: (f32(p) - u).astype(p.dtype), params, upd)
    return new_params, new_state, metrics
