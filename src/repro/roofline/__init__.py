from .analysis import (
    HBM_BW, ICI_BW, PEAK_FLOPS,
    CollectiveStats, collective_summary, model_flops, parse_collectives,
    roofline_terms, summarize_cell,
)

__all__ = [
    "HBM_BW", "ICI_BW", "PEAK_FLOPS",
    "CollectiveStats", "collective_summary", "model_flops",
    "parse_collectives", "roofline_terms", "summarize_cell",
]
