"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Terms (per device, TPU v5e):
    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = wire_bytes_per_device / ICI_bw

``cost_analysis()`` yields per-device FLOPs and bytes for the SPMD
partitioned module.  Collective wire bytes are parsed from the optimized
HLO: for each all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, ring-algorithm wire volume per participant is derived
from the result shape and replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

# TPU v5e constants (task spec)
PEAK_FLOPS = 197e12           # bf16 FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (use 1 link conservatively)
# Fixed per-collective cost (launch + ring setup + per-hop latency), used by
# the bucket-size autotuner (optim/buckets.resolve_bucket_bytes).  Set to
# None on parts where it isn't characterized — consumers must fall back to
# their static defaults.
ICI_LATENCY_S = 2e-6

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\(?[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<variant>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


@dataclasses.dataclass
class CollectiveStats:
    op: str
    result_bytes: int
    group_size: int
    wire_bytes: int
    count: int = 1


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))      # [n_groups, group_size]<=[...]
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_bytes(op: str, result_bytes: int, g: int) -> int:
    """Ring-algorithm wire volume per participant."""
    if g <= 1:
        return 0
    if op == "all-gather":
        return int(result_bytes * (g - 1) / g)
    if op == "reduce-scatter":
        return int(result_bytes * (g - 1))          # operand = g * result
    if op == "all-reduce":
        return int(2 * result_bytes * (g - 1) / g)
    if op == "all-to-all":
        return int(result_bytes * (g - 1) / g)
    if op == "collective-permute":
        return result_bytes
    return 0


def parse_collectives(hlo_text: str) -> list[CollectiveStats]:
    out: dict[tuple, CollectiveStats] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("variant") == "-done":
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("type"))
        g = _group_size(line)
        wb = _wire_bytes(op, rb, g)
        key = (op, rb, g)
        if key in out:
            out[key].count += 1
            out[key].wire_bytes += wb
        else:
            out[key] = CollectiveStats(op, rb, g, wb)
    return sorted(out.values(), key=lambda c: -c.wire_bytes)


def collective_summary(stats: list[CollectiveStats]) -> dict:
    total = sum(c.wire_bytes for c in stats)
    by_op: dict[str, int] = {}
    for c in stats:
        by_op[c.op] = by_op.get(c.op, 0) + c.wire_bytes
    return {"total_wire_bytes": total, "by_op": by_op,
            "n_collectives": sum(c.count for c in stats)}


# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — the 'useful' FLOPs.

    Training counts fwd+bwd (6ND); inference counts forward only (2ND).
    D = tokens processed by the step.
    """
    n = cfg.n_params_active
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_terms(cost: dict, coll_total_bytes: int, *, n_chips: int) -> dict:
    """cost: compiled.cost_analysis() of the per-device SPMD module."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_total_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["flops_per_device"] = flops_dev
    terms["bytes_per_device"] = bytes_dev
    terms["wire_bytes_per_device"] = float(coll_total_bytes)
    # roofline-optimal step time = max of the three (perfect overlap)
    terms["bound_s"] = max(t_compute, t_memory, t_coll)
    return terms


def summarize_cell(cfg, shape, cost: dict, mem, hlo_text: str, n_chips: int) -> dict:
    """Roofline summary; FLOPs/bytes/collectives from the trip-count-aware
    static HLO analysis (hlo_cost.py — ``cost_analysis()`` counts while
    bodies once, so scans would be undercounted by their trip counts)."""
    from .hlo_cost import analyze

    hc = analyze(hlo_text)
    csum = hc.collective_summary()
    exact = {"flops": hc.flops, "bytes accessed": hc.bytes}
    terms = roofline_terms(exact, csum["total_wire_bytes"], n_chips=n_chips)
    terms["collective_s_bf16norm"] = csum["total_wire_bytes_bf16norm"] / ICI_BW
    mf = model_flops(cfg, shape)
    hlo_total = terms["flops_per_device"] * n_chips
    terms["model_flops_total"] = mf
    terms["useful_flops_ratio"] = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: useful-FLOPs time / achievable bound
    t_useful = mf / n_chips / PEAK_FLOPS
    terms["roofline_fraction"] = t_useful / terms["bound_s"] if terms["bound_s"] else 0.0
    out = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "n_chips": n_chips,
        "terms": terms,
        "collectives": csum,
        "top_collectives": hc.top_collectives(8),
        "xla_cost_analysis": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        },
    }
    if mem is not None:
        out["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        }
    return out
