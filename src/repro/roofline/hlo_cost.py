"""Trip-count-aware static cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any model
built on ``lax.scan`` (layers, microbatches, attention chunks) is
undercounted by the trip count.  XLA records the statically-known trip
count on each while op (``backend_config={"known_trip_count":{"n":...}}``),
so exact accounting is recoverable from the artifact itself:

    total(op) = op_cost x prod(trip counts of enclosing whiles)

This module parses the optimized HLO module text, builds the computation
call graph (while bodies, fusions, calls, conditionals), and accumulates:

* FLOPs        — exact for ``dot`` (2 x prod(result) x prod(contracting)),
                 1/elem for elementwise arithmetic, recursed into fusions;
* bytes        — operand + result bytes per memory-touching op (fusion
                 interiors excluded, matching HloCostAnalysis semantics);
* collectives  — ring wire volume per participant, times multiplicity.

Validated against ``compiled.cost_analysis()`` on fully-unrolled probes
(tests/test_roofline.py) where both must agree.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

# ops that move no data / are free
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
# transcendental-ish elementwise (count a few flops per element)
_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "logistic",
    "sine", "cosine", "erf", "exponential-minus-one", "log-plus-one", "atan2",
}
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "not", "xor", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "remainder",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "all-to-all-start", "reduce-scatter-start",
}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str          # operands + attributes (single line)
    elems: int
    bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list


@dataclasses.dataclass
class CollectiveRecord:
    op: str
    result_bytes: int
    group_size: int
    wire_bytes_once: int
    multiplicity: float
    count: int = 1
    is_f32: bool = False

    @property
    def wire_bytes(self) -> float:
        return self.wire_bytes_once * self.multiplicity * self.count

    @property
    def wire_bytes_bf16(self) -> float:
        """TPU-normalised: f32 tensors at matmul boundaries would be bf16."""
        return self.wire_bytes * (0.5 if self.is_f32 else 1.0)


def parse_module(text: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    hdr_start = re.compile(r"^(ENTRY\s+)?%[\w.\-]+\s*\(")
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and hdr_start.match(line):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [])
                    if line.lstrip().startswith("ENTRY"):
                        entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, op, rest = m.groups()
            elems, b = _shape_elems_bytes(type_str)
            cur.instrs.append(Instr(name, type_str, op, rest, elems, b))
    if cur is not None:
        comps[cur.name] = cur
    if entry is None:
        # fall back: last computation
        entry = list(comps)[-1]
    return comps, entry


def _wire_bytes(op: str, result_bytes: int, g: int) -> int:
    op = op.replace("-start", "")
    if g <= 1:
        return 0
    if op == "all-gather":
        return int(result_bytes * (g - 1) / g)
    if op == "reduce-scatter":
        return int(result_bytes * (g - 1))
    if op == "all-reduce":
        return int(2 * result_bytes * (g - 1) / g)
    if op == "all-to-all":
        return int(result_bytes * (g - 1) / g)
    if op == "collective-permute":
        return result_bytes
    return 0


_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _operand_names(rest: str) -> list[str]:
    """Operand names of an instruction whose ``rest`` begins right after the
    op's opening paren.  Walks to the matching close paren (operand types may
    be printed inline and contain commas/brackets; tuple types contain
    balanced parens) and extracts the ``%name`` tokens inside.  HLO printed
    without ``%`` sigils (some dump modes) falls back to the last bare token
    of each top-level comma segment."""
    depth = 1
    end = len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    ops = rest[:end]
    names = _OPERAND_NAME.findall(ops)
    if names or "%" in ops or not ops.strip():
        return names
    # sigil-free format: 'add(a, b)' or 'add(f32[2] a, f32[2] b)'
    out = []
    for seg in ops.split(","):
        toks = seg.strip().split()
        if toks:
            out.append(toks[-1])
    return out


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 1


class HloCost:
    """Trip-count-aware cost walk.

    Two TPU-normalisations of CPU-backend lowering artifacts (documented in
    EXPERIMENTS.md §Roofline methodology):

    * ``rs_pattern``: XLA:CPU lacks the ReduceScatterCreator pass, so a TP
      partial-sum lowers as all-reduce + partition-offset dynamic-slice.
      On TPU this is a reduce-scatter at half the wire bytes; all-reduces
      whose only consumer is a dynamic-slice are charged as RS.
    * ``bf16_wire``: XLA:CPU legalizes bf16 dots to f32 and elides the
      casts, so every matmul-adjacent collective rides f32 (2x the TPU
      wire).  ``collective_bf16_bytes`` reports f32 collectives at bf16.
    """

    def __init__(self, text: str, rs_pattern: bool = True):
        self.comps, self.entry = parse_module(text)
        self.symbols: dict[str, dict[str, Instr]] = {
            c.name: {i.name: i for i in c.instrs} for c in self.comps.values()
        }
        self.flops = 0.0
        self.bytes = 0.0
        self.collectives: dict[tuple, CollectiveRecord] = {}
        self._rs_names: dict[str, set] = {}
        if rs_pattern:
            self._find_rs_patterns()
        self._walk(self.entry, 1.0, set())

    def _find_rs_patterns(self):
        """Per computation: names of all-reduce ops whose only consumer is a
        dynamic-slice (the CPU lowering of reduce-scatter)."""
        for comp in self.comps.values():
            ar = {i.name for i in comp.instrs
                  if i.op in ("all-reduce", "all-reduce-start")}
            if not ar:
                continue
            consumers: dict[str, list] = {a: [] for a in ar}
            for ins in comp.instrs:
                for tok in _operand_names(ins.rest):
                    if tok in consumers:
                        consumers[tok].append(ins.op)
            self._rs_names[comp.name] = {
                a for a, cons in consumers.items()
                if cons and all(c in ("dynamic-slice", "all-reduce-done")
                                for c in cons)
            }

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: str, ins: Instr) -> float:
        names = _operand_names(ins.rest)
        lhs = self.symbols[comp].get(names[0]) if names else None
        contract = 1
        m = _LHS_CONTRACT.search(ins.rest)
        if lhs is not None and m and m.group(1):
            dims_m = _SHAPE.search(lhs.type_str)
            if dims_m:
                lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for idx in m.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
        return 2.0 * ins.elems * contract

    def _walk(self, comp_name: str, mult: float, stack: set):
        if comp_name not in self.comps or comp_name in stack:
            return
        comp = self.comps[comp_name]
        stack = stack | {comp_name}
        for ins in comp.instrs:
            op = ins.op
            if op in _FREE:
                continue
            if op in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                g = _group_size(ins.rest)
                opname = op.replace("-start", "")
                if opname == "all-reduce" and \
                        ins.name in self._rs_names.get(comp.name, ()):
                    opname = "reduce-scatter(AR+slice)"
                    wb = _wire_bytes("all-reduce", ins.bytes, g) // 2
                else:
                    wb = _wire_bytes(op, ins.bytes, g)
                f32 = ins.type_str.lstrip("(").startswith("f32")
                key = (opname, ins.bytes, g, mult)
                rec = self.collectives.get(key)
                if rec:
                    rec.count += 1
                else:
                    self.collectives[key] = CollectiveRecord(
                        opname, ins.bytes, g, wb, mult, is_f32=f32
                    )
                self.bytes += 2 * ins.bytes * mult
                continue
            if op == "while":
                n = 1
                m = _TRIP.search(ins.rest)
                if m:
                    n = int(m.group(1))
                mcalls = re.findall(r"(?:body|condition)=%?([\w.\-]+)", ins.rest)
                for callee in mcalls:
                    self._walk(callee, mult * n, stack)
                continue
            if op == "conditional":
                m = _COND_BRANCHES.search(ins.rest)
                if m:
                    for callee in m.group(1).split(","):
                        self._walk(callee.strip().lstrip("%"), mult, stack)
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "custom-call", "async-start"):
                # memory: result + effective operand bytes.  An operand that
                # the fusion body only *slices* (dynamic-slice/gather: the
                # per-layer weight slice of a scan-stacked parameter) is
                # charged at the sliced size, not the full buffer.
                callees = _CALLS.findall(ins.rest)
                if op == "fusion" and callees:
                    eff = self._fusion_operand_bytes(comp.name, ins, callees[0])
                else:
                    eff = self._operand_bytes(comp.name, ins)
                self.bytes += (ins.bytes + eff) * mult
                # flops: recurse into called computations (fusion interior)
                for callee in _CALLS.findall(ins.rest):
                    self._walk_flops_only(callee, mult, stack, scale=ins.elems
                                          if op in ("reduce", "map", "reduce-window")
                                          else 1)
                continue
            # indexing ops read/write only the sliced region, not the operand
            if op in ("dynamic-slice", "slice", "gather"):
                self.bytes += 2 * ins.bytes * mult
                continue
            if op in ("dynamic-update-slice",):
                upd = self._nth_operand_bytes(comp.name, ins, 1)
                self.bytes += 2 * upd * mult
                continue
            # plain op
            self.bytes += (ins.bytes + self._operand_bytes(comp.name, ins)) * mult
            if op == "dot":
                self.flops += self._dot_flops(comp.name, ins) * mult
            elif op == "convolution":
                self.flops += 2.0 * ins.elems * mult  # lower bound
            elif op in _TRANSCENDENTAL:
                self.flops += 4.0 * ins.elems * mult
            elif op in _ELEMENTWISE or op in ("convert", "reduce-precision"):
                self.flops += 1.0 * ins.elems * mult

    def _walk_flops_only(self, comp_name: str, mult: float, stack: set,
                         scale: float = 1):
        """Accumulate flops (not bytes) of a called computation."""
        if comp_name not in self.comps or comp_name in stack:
            return
        comp = self.comps[comp_name]
        stack = stack | {comp_name}
        for ins in comp.instrs:
            op = ins.op
            if op in _FREE or op in _COLLECTIVES:
                continue
            if op == "dot":
                self.flops += self._dot_flops(comp.name, ins) * mult
            elif op in _TRANSCENDENTAL:
                self.flops += 4.0 * ins.elems * mult
            elif op in _ELEMENTWISE or op == "convert":
                self.flops += 1.0 * ins.elems * mult
            for callee in _CALLS.findall(ins.rest):
                self._walk_flops_only(callee, mult, stack)

    def _param_effective_bytes(self, callee: str) -> dict[int, int] | None:
        """For a fusion computation: parameter index -> effective bytes for
        params consumed ONLY by slice-like ops (else absent)."""
        if callee not in self.comps:
            return None
        cache = getattr(self, "_eff_cache", None)
        if cache is None:
            cache = self._eff_cache = {}
        if callee in cache:
            return cache[callee]
        comp = self.comps[callee]
        params: dict[str, int] = {}      # name -> index
        for ins in comp.instrs:
            if ins.op == "parameter":
                head = ins.rest.split(")")[0]
                params[ins.name] = int(head) if head.isdigit() else len(params)
        eff: dict[int, int] = {}
        sliceish = {"dynamic-slice", "slice", "gather"}
        for pname, pidx in params.items():
            consumers = []
            ok = True
            for ins in comp.instrs:
                if ins.op == "parameter":
                    continue
                names = _operand_names(ins.rest)
                if pname in names:
                    if ins.op in sliceish and names[0] == pname:
                        consumers.append(ins.bytes)
                    elif ins.op == "dynamic-update-slice" and names[0] == pname:
                        upd = self.symbols[callee].get(names[1] if len(names) > 1 else "")
                        consumers.append(upd.bytes if upd else ins.bytes)
                    else:
                        ok = False
                        break
            if ok and consumers:
                eff[pidx] = sum(consumers)
        cache[callee] = eff
        return eff

    def _fusion_operand_bytes(self, comp: str, ins: Instr, callee: str) -> int:
        eff = self._param_effective_bytes(callee)
        total = 0
        for i, tok in enumerate(_operand_names(ins.rest)):
            sym = self.symbols[comp].get(tok)
            if sym is None:
                continue
            if eff is not None and i in eff:
                total += min(eff[i], sym.bytes)
            else:
                total += sym.bytes
        return total

    def _nth_operand_bytes(self, comp: str, ins: Instr, n: int) -> int:
        toks = _operand_names(ins.rest)
        if n < len(toks):
            sym = self.symbols[comp].get(toks[n])
            if sym is not None:
                return sym.bytes
        return ins.bytes

    def _operand_bytes(self, comp: str, ins: Instr) -> int:
        total = 0
        for tok in _operand_names(ins.rest):
            sym = self.symbols[comp].get(tok)
            if sym is not None:
                total += sym.bytes
        return total

    # ------------------------------------------------------------------
    def collective_summary(self) -> dict:
        total = sum(r.wire_bytes for r in self.collectives.values())
        total_bf16 = sum(r.wire_bytes_bf16 for r in self.collectives.values())
        by_op: dict[str, float] = {}
        for r in self.collectives.values():
            by_op[r.op] = by_op.get(r.op, 0.0) + r.wire_bytes
        return {
            "total_wire_bytes": total,
            "total_wire_bytes_bf16norm": total_bf16,
            "by_op": by_op,
            "n_collective_sites": len(self.collectives),
        }

    def top_collectives(self, k: int = 10) -> list[dict]:
        recs = sorted(self.collectives.values(), key=lambda r: -r.wire_bytes)
        return [
            {
                "op": r.op, "result_bytes": r.result_bytes,
                "group_size": r.group_size, "multiplicity": r.multiplicity,
                "count": r.count, "wire_bytes": r.wire_bytes,
            }
            for r in recs[:k]
        ]


def analyze(text: str) -> HloCost:
    return HloCost(text)
