from .loop import ServeConfig, generate
from .step import jit_decode_step, jit_prefill

__all__ = ["ServeConfig", "generate", "jit_decode_step", "jit_prefill"]
