from .cache import (
    KeyMirror,
    RecurrentCache,
    bucket_for,
    make_slot_state,
    prompt_buckets,
    slot_state_specs,
)
from .engine import STATUSES, Completion, EngineConfig, ServeEngine
from .faults import (
    ENGINE_FAULT_SITES,
    FAULT_SITES,
    NONFINITE_TOKEN,
    REPLICA_FAULT_SITES,
    FaultPlan,
)
from .loop import ServeConfig, generate, generate_static
from .router import ReplicaHandle, Router, RouterConfig
from .paged import (
    BlockAllocator,
    HostTier,
    LaneSpill,
    SlotTables,
    blocks_for,
    check_tiered,
    make_paged_state,
    paged_state_specs,
    prefix_keys,
)
from .step import (
    jit_decode_step,
    jit_prefill,
    paged_copy_program,
    paged_decode_program,
    paged_prefill_program,
    sample_tokens,
    slot_decode_program,
    slot_prefill_program,
)

__all__ = [
    "Completion", "EngineConfig", "ServeEngine", "STATUSES",
    "FaultPlan", "FAULT_SITES", "ENGINE_FAULT_SITES",
    "REPLICA_FAULT_SITES", "NONFINITE_TOKEN",
    "Router", "RouterConfig", "ReplicaHandle",
    "ServeConfig", "generate", "generate_static",
    "KeyMirror", "RecurrentCache", "bucket_for", "make_slot_state",
    "prompt_buckets", "slot_state_specs",
    "BlockAllocator", "HostTier", "LaneSpill", "SlotTables", "blocks_for",
    "check_tiered", "make_paged_state", "paged_state_specs", "prefix_keys",
    "jit_decode_step", "jit_prefill", "sample_tokens",
    "slot_decode_program", "slot_prefill_program",
    "paged_copy_program", "paged_decode_program", "paged_prefill_program",
]
