"""Slotted cache for the continuous-batching serve engine.

For the KV families the cache is a fixed tensor of ``max_slots`` lanes x
``max_len`` positions (per layer/head as the model family dictates).  A
*slot* is one lane: admission prefills a prompt into a free lane, decode
advances every active lane by one token per step, and eviction just
clears the lane's ``active`` bit — the lane's stale KV is overwritten
lazily (positions are only ever attended at ``pos <= length`` and each
position is rewritten by the decode step before the sequence first
attends it, so garbage left by a previous occupant is never read).

For the *recurrent* state kinds (ssm/xlstm; zamba's mamba leaves) there
is no position axis — each lane's state is O(1) in sequence length, and
the lazy-overwrite argument doesn't apply (decode rewrites the WHOLE
state every step, so an evicted lane's stale state would keep evolving).
:class:`RecurrentCache` manages those leaves: admission hard-resets a
lane (``prefill_slot`` writes the complete state snapshot), and the
decode/prefill programs zero every inactive lane's leaves
(:meth:`RecurrentCache.freeze`), so "inactive lane state == 0" is an
invariant the tests sweep.

All per-slot scheduling state lives **on device** in small vectors so the
decode loop's only host sync is the sampled-token fetch:

    tokens   (N,) int32  last sampled token per slot (next decode input)
    lengths  (N,) int32  tokens currently in the lane's cache
    active   (N,) bool   lane is serving a live request
    limits   (N,) int32  cache length at which the final token is sampled
    temps    (N,) f32    per-slot sampling temperature (0 = greedy)
    top_ks   (N,) int32  per-slot top-k mask (0 = off)
    top_ps   (N,) f32    per-slot nucleus threshold (<=0 or >=1 = off)
    replay   (N,) bool   lane is replaying a preemption resume: its next
                         decode input is host-forced, so on-device "done"
                         verdicts are advisory (recurrent freeze must not
                         zero the lane's state)
    key      PRNG key    split once per engine step (deterministic per seed)

Prompt lengths are **bucketed** (powers of two by default) so one prefill
executable per bucket serves every admission — the AOT dispatch cache
stays flat after warmup instead of compiling per prompt length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.models.attention import DecodeSharding

DEFAULT_MIN_BUCKET = 16


def prompt_buckets(max_len: int, min_bucket: int = DEFAULT_MIN_BUCKET) -> tuple[int, ...]:
    """Power-of-two prompt-length buckets, capped at ``max_len``."""
    if max_len < 1:
        raise ValueError(f"max_len must be positive, got {max_len}")
    if min_bucket < 1:
        raise ValueError(f"min_bucket must be positive, got {min_bucket}")
    out: list[int] = []
    b = min(min_bucket, max_len)
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def bucket_for(plen: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that fits a prompt of length ``plen``."""
    if plen < 1:
        raise ValueError(f"prompt length must be positive, got {plen}")
    if not buckets:
        raise ValueError("no prompt buckets configured")
    for b in buckets:
        if b >= plen:
            return b
    raise ValueError(
        f"prompt length {plen} exceeds the largest bucket {buckets[-1]}"
    )


class RecurrentCache:
    """Per-lane recurrent-state manager for the slotted serve engine.

    Wraps :func:`repro.models.registry.recurrent_leaf_axes`: ``leaf_axes``
    maps each recurrent cache leaf (e.g. xlstm's ``m_C`` or zamba's
    ``ssm``) to its lane axis.  Falsy for pure-KV families, so engine and
    program builders can gate on ``if rec:``.

    Lifecycle invariants (asserted in tests/test_serve_engine.py):

    * **admit-time reset** — ``prefill_slot`` overwrites the lane's
      recurrent leaves wholesale with the state snapshot at the prompt
      end; nothing of a previous occupant survives.
    * **evict-time zeroing** — every decode/prefill program passes its
      post-step ``active`` vector through :meth:`freeze`, which zeroes
      the recurrent leaves of every inactive lane *in the same
      executable* (a lane finishing on-device is zeroed in the step that
      finishes it).  So after any fused-sampling step, an inactive
      lane's recurrent state is exactly zero — no stale recurrence ever
      advances, and no inf/NaN can accumulate in dead lanes.
    """

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.leaf_axes: dict[str, int] = registry.recurrent_leaf_axes(cfg)

    def __bool__(self) -> bool:
        return bool(self.leaf_axes)

    def _bcast(self, active, leaf, axis: int):
        shape = [1] * leaf.ndim
        shape[axis] = active.shape[0]
        return active.reshape(shape)

    def freeze(self, cache: dict, active) -> dict:
        """Zero the recurrent leaves of every lane whose ``active`` bit is
        False (``(max_slots,)`` bool).  Active lanes pass through bitwise
        (``where`` with a True predicate)."""
        out = dict(cache)
        for name, axis in self.leaf_axes.items():
            leaf = cache[name]
            out[name] = jnp.where(
                self._bcast(active, leaf, axis), leaf,
                jnp.zeros((), leaf.dtype))
        return out

    def snapshot(self, cache: dict) -> dict:
        """The recurrent leaves of ``cache`` as a flat dict — a cheap
        per-lane-state copy (XLA aliases the arrays; a later ``where``
        against the snapshot is the only materialization).  Used by the
        speculative verify program to roll lanes back to their last
        committed step."""
        return {name: cache[name] for name in self.leaf_axes}

    def rollback(self, cache: dict, snap: dict, keep) -> dict:
        """Per-lane select between ``cache`` (lanes where ``keep`` is
        True) and the earlier ``snapshot`` ``snap`` (lanes where it is
        False).  ``keep`` is ``(max_slots,)`` bool.  Kept lanes pass
        through bitwise (``where`` with a True predicate), so a lane that
        accepted every speculative token is untouched and a lane that
        rejected is bitwise the state it had before the rejected steps
        ran — the property tests/test_spec_decode.py pins."""
        out = dict(cache)
        for name, axis in self.leaf_axes.items():
            out[name] = jnp.where(
                self._bcast(keep, cache[name], axis), cache[name],
                snap[name])
        return out

    def lane_is_zero(self, cache: dict, slot: int) -> bool:
        """Host-side check: lane ``slot``'s recurrent leaves are all
        exactly zero (the evict-time-zeroing invariant)."""
        return self.lanes_are_zero(cache, [slot])

    def lanes_are_zero(self, cache: dict, slots) -> bool:
        """``lane_is_zero`` over several lanes with ONE host fetch per
        leaf (the invariant sweep runs after every fuzzer step — per-lane
        fetches of whole leaves would multiply transfers)."""
        slots = list(slots)
        if not slots:
            return True
        for name, axis in self.leaf_axes.items():
            lanes = np.take(np.asarray(cache[name]), slots, axis=axis)
            if np.any(lanes != 0):
                return False
        return True


class KeyMirror:
    """Host-side mirror of the device PRNG key stream.

    Every serve executable (prefill chunk / decode step) splits
    ``state["key"]`` exactly once per call.  In the host-sampling ablation
    the sampler runs on the host from fetched logits, but draws its
    randomness from this mirror — replaying the same splits in executable
    order — so at a fixed engine seed the host path samples the *same*
    tokens as the fused on-device sampler (asserted in
    ``tests/test_serve_engine.py::test_host_vs_fused_sampler_parity``).
    """

    def __init__(self, seed: int):
        self.key = jax.random.PRNGKey(seed).astype(jnp.uint32)

    def split(self):
        """Advance the stream one executable call; returns the subkey the
        device-side program would have fed its sampler."""
        self.key, sub = jax.random.split(self.key)
        return sub


def sched_specs(mesh, max_slots: int):
    """Per-slot scheduling vectors shared by the slotted and paged layouts:
    ``({leaf: sds}, {leaf: NamedSharding})`` (all replicated)."""
    rep = NamedSharding(mesh, P())
    n = max_slots
    sds = {
        "tokens": jax.ShapeDtypeStruct((n,), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((n,), jnp.int32),
        "active": jax.ShapeDtypeStruct((n,), jnp.bool_),
        "limits": jax.ShapeDtypeStruct((n,), jnp.int32),
        "temps": jax.ShapeDtypeStruct((n,), jnp.float32),
        "top_ks": jax.ShapeDtypeStruct((n,), jnp.int32),
        "top_ps": jax.ShapeDtypeStruct((n,), jnp.float32),
        "replay": jax.ShapeDtypeStruct((n,), jnp.bool_),
        "key": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }
    sh = {k: rep for k in sds}
    return sds, sh


def slot_state_specs(cfg: ArchConfig, mesh, max_slots: int, max_len: int):
    """Abstract slot state: ``({leaf: sds}, {leaf: NamedSharding})``."""
    mod = registry.get_module(cfg)
    dec = DecodeSharding.choose(mesh, max_slots)
    cache_sds = mod.make_cache_specs(cfg, max_slots, max_len)
    cache_ps = mod.cache_pspec(cfg, dec)
    sched_sds, sched_sh = sched_specs(mesh, max_slots)
    sds = {"cache": cache_sds, **sched_sds}
    sh = {
        "cache": jax.tree.map(
            lambda p: NamedSharding(mesh, p), cache_ps,
            is_leaf=lambda x: isinstance(x, P),
        ),
        **sched_sh,
    }
    return sds, sh


def make_slot_state(cfg: ArchConfig, mesh, max_slots: int, max_len: int,
                    seed: int = 0) -> dict:
    """Allocate the device-resident slot state (all lanes free)."""
    sds, sh = slot_state_specs(cfg, mesh, max_slots, max_len)
    state = jax.tree.map(
        lambda s, d: jax.device_put(jnp.zeros(s.shape, s.dtype), d), sds, sh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    state["key"] = jax.device_put(
        jax.random.PRNGKey(seed).astype(jnp.uint32), sh["key"]
    )
    return state


def state_sds(state) -> dict:
    """ShapeDtypeStructs of a live state tree (for AOT lowering)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
