"""Continuous-batching serve engine over the slotted KV cache.

The engine runs one fixed-shape decode executable over ``max_slots`` cache
lanes.  Requests are admitted into free lanes at *any* decode step (prefill
through a length-bucketed executable), finished sequences are evicted
immediately (EOS or token budget), and sampling is fused into the decode
program — the per-step host sync is a single ``(max_slots,)`` int32 token
fetch instead of a logits round-trip.

Every executable is AOT-compiled once per static key through an
:class:`~repro.core.aot.AotCache` — ``(config, bucketed prompt length,
max_slots, sampler options)`` — so steady-state dispatch is a dict probe:
after warmup the engine's ``builds`` counter must stay flat (asserted by
``benchmarks/serve_bench.py --smoke`` in CI).

Host-side the engine keeps a mirror of the scheduling vectors (lengths,
budgets, which request owns which lane).  The mirror is advanced by the
same rules the device applies, so the engine never reads device state
back except the sampled tokens it needs to stream anyway.

    engine = ServeEngine(cfg, mesh, rules, params,
                         EngineConfig(max_slots=8, max_len=256))
    rid = engine.submit(prompt_ids, max_new_tokens=32, temperature=0.7)
    engine.drain()                       # or step() under an arrival loop
    out = engine.completions[rid].tokens
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.aot import AotCache
from repro.models import registry
from repro.models.common import ShardRules
from repro.train.step import shardings_for
from .cache import bucket_for, make_slot_state, prompt_buckets, slot_state_specs, state_sds
from .step import slot_decode_program, slot_prefill_program


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8            # cache lanes decoded per step
    max_len: int = 256            # fixed per-lane cache length
    eos_id: int | None = None     # None: budget-only eviction
    top_k: int = 0                # 0: no top-k mask in the fused sampler
    seed: int = 0
    # prompt-length buckets for the prefill executables; None -> powers of
    # two up to max_len (one AOT build per bucket ever used)
    prefill_buckets: tuple[int, ...] | None = None
    # False: benchmark baseline — logits round-trip to host sampling
    fused_sampling: bool = True


@dataclasses.dataclass
class _Slot:
    rid: int
    plen: int
    limit: int                    # cache length at which the last token samples
    temperature: float
    generated: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    max_new_tokens: int
    tokens: list[int]
    token_times: list[float]      # clock() when each token reached the host
    submit_time: float
    finish_time: float


@dataclasses.dataclass
class _Pending:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float
    submit_time: float


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        mesh,
        rules: ShardRules,
        params,
        engine: EngineConfig = EngineConfig(),  # noqa: B008 - frozen, never mutated
        *,
        aot: AotCache | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if not registry.supports_slot_serving(cfg):
            raise ValueError(
                f"family {cfg.family!r} does not support slot serving; "
                "use serve.loop.generate_static"
            )
        self.cfg, self.mesh, self.rules = cfg, mesh, rules
        self.econ = engine
        self.buckets = tuple(engine.prefill_buckets or prompt_buckets(engine.max_len))
        if max(self.buckets) > engine.max_len:
            raise ValueError("prefill bucket exceeds max_len")
        self.aot = aot or AotCache("serve")
        self.clock = clock

        self._p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
        self._rep = NamedSharding(mesh, P())
        self.params = jax.device_put(params, self._p_sh)
        self._params_sds = registry.abstract_params(cfg)
        _, self._state_sh = slot_state_specs(cfg, mesh, engine.max_slots, engine.max_len)
        self.state = make_slot_state(
            cfg, mesh, engine.max_slots, engine.max_len, engine.seed)
        self._state_sds = state_sds(self.state)

        self.queue: deque[_Pending] = deque()
        self.slots: list[_Slot | None] = [None] * engine.max_slots
        self.live: dict[int, Completion] = {}
        self.completions: dict[int, Completion] = {}
        self.counters = {
            "prefills": 0, "decode_steps": 0,
            "admitted": 0, "evicted": 0, "dead_slot_steps": 0,
        }
        self._next_rid = 0
        self._host_rng = np.random.default_rng(engine.seed)
        # host mirrors only needed when sampling is not fused
        self._tok_mirror = np.zeros(engine.max_slots, np.int32)
        self._active_mirror = np.zeros(engine.max_slots, bool)

    # ------------------------------------------------------------------
    # Executables (AOT via the shared cache)
    # ------------------------------------------------------------------
    def _sampler_key(self) -> tuple:
        e = self.econ
        return (self.cfg.name, e.max_slots, e.max_len, e.top_k, e.eos_id,
                e.fused_sampling)

    def _decode_exe(self):
        key = ("slot_decode",) + self._sampler_key()

        def build():
            fn = slot_decode_program(
                self.cfg, self.mesh, self.rules, top_k=self.econ.top_k,
                eos_id=self.econ.eos_id, fused=self.econ.fused_sampling,
            )
            jitted = jax.jit(
                fn, in_shardings=(self._p_sh, self._state_sh),
                # pin state outputs to the canonical shardings so decode
                # and prefill executables hand the state back and forth
                # without resharding (AOT calls check shardings exactly)
                out_shardings=(self._state_sh, self._rep),
                donate_argnums=(1,),
            )
            return jitted.lower(self._params_sds, self._state_sds).compile()

        return self.aot.get(key, build)

    def _prefill_exe(self, bucket: int):
        key = ("slot_prefill", bucket) + self._sampler_key()

        def build():
            fn = slot_prefill_program(
                self.cfg, self.mesh, self.rules, top_k=self.econ.top_k,
                eos_id=self.econ.eos_id, fused=self.econ.fused_sampling,
            )
            rep = self._rep
            jitted = jax.jit(
                fn,
                in_shardings=(self._p_sh, self._state_sh, rep, rep, rep, rep, rep),
                out_shardings=(self._state_sh, rep),
                donate_argnums=(1,),
            )
            i32 = lambda shape=(): jax.ShapeDtypeStruct(shape, jnp.int32)
            return jitted.lower(
                self._params_sds, self._state_sds, i32((1, bucket)),
                i32(), i32(), i32(), jax.ShapeDtypeStruct((), jnp.float32),
            ).compile()

        return self.aot.get(key, build)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 16,
               temperature: float = 0.0, rid: int | None = None) -> int:
        """Queue a request; returns its request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        bucket_for(prompt.size, self.buckets)  # raises if it can't fit
        if prompt.size + max_new_tokens - 1 > self.econ.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.econ.max_len}"
            )
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.queue.append(_Pending(
            rid, prompt, max_new_tokens, float(temperature), self.clock()))
        return rid

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def _put(self, x, dtype):
        return jax.device_put(jnp.asarray(x, dtype), self._rep)

    def _admit(self, req: _Pending, slot: int) -> None:
        plen = int(req.prompt.size)
        bucket = bucket_for(plen, self.buckets)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = req.prompt
        limit = plen + req.max_new_tokens - 1
        exe = self._prefill_exe(bucket)
        self.state, out = exe(
            self.params, self.state, self._put(padded, jnp.int32),
            self._put(slot, jnp.int32), self._put(plen, jnp.int32),
            self._put(limit, jnp.int32), self._put(req.temperature, jnp.float32),
        )
        self.counters["prefills"] += 1
        self.counters["admitted"] += 1

        if self.econ.fused_sampling:
            tok = int(np.asarray(out)[0])
        else:
            tok = int(self._host_sample(
                np.asarray(out), np.array([req.temperature]))[0])
        now = self.clock()
        comp = Completion(
            rid=req.rid, prompt_len=plen, max_new_tokens=req.max_new_tokens,
            tokens=[tok], token_times=[now], submit_time=req.submit_time,
            finish_time=0.0,
        )
        self.live[req.rid] = comp
        self.slots[slot] = _Slot(req.rid, plen, limit, req.temperature, generated=1)
        self._tok_mirror[slot] = tok
        done = (req.max_new_tokens == 1) or (
            self.econ.eos_id is not None and tok == self.econ.eos_id)
        self._active_mirror[slot] = not done
        if done:
            self._finish(slot, now)
        if not self.econ.fused_sampling:
            self._writeback_sampled()

    def _finish(self, slot: int, now: float) -> None:
        s = self.slots[slot]
        comp = self.live.pop(s.rid)
        comp.finish_time = now
        self.completions[s.rid] = comp
        self.slots[slot] = None
        self._active_mirror[slot] = False
        self.counters["evicted"] += 1

    def _host_sample(self, logits: np.ndarray, temps: np.ndarray) -> np.ndarray:
        """Benchmark baseline: sample on host from full logits (M, V)."""
        logits = np.asarray(logits, np.float32)
        out = np.argmax(logits, axis=-1).astype(np.int32)
        for i, t in enumerate(temps):
            if t > 0:
                z = logits[i] / t
                z -= z.max()
                p = np.exp(z)
                out[i] = self._host_rng.choice(logits.shape[-1], p=p / p.sum())
        return out

    def _writeback_sampled(self) -> None:
        """Host-sampling mode: push tokens/active back to device state."""
        self.state["tokens"] = self._put(self._tok_mirror, jnp.int32)
        self.state["active"] = self._put(self._active_mirror, jnp.bool_)

    # ------------------------------------------------------------------
    # The serving loop
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit every queued request a free slot can take, then advance
        all active lanes by one token.  Returns False when idle."""
        progressed = False
        for slot in self.free_slots():
            if not self.queue:
                break
            self._admit(self.queue.popleft(), slot)
            progressed = True

        active_slots = [i for i, s in enumerate(self.slots) if s is not None]
        if active_slots:
            exe = self._decode_exe()
            self.state, out = exe(self.params, self.state)
            self.counters["decode_steps"] += 1
            self.counters["dead_slot_steps"] += (
                self.econ.max_slots - len(active_slots))
            if self.econ.fused_sampling:
                toks = np.asarray(out)          # the one per-step host sync
            else:
                temps = np.array([
                    s.temperature if s is not None else 0.0 for s in self.slots
                ])
                toks = self._host_sample(np.asarray(out), temps)
            now = self.clock()
            for i in active_slots:
                s = self.slots[i]
                tok = int(toks[i])
                s.generated += 1
                comp = self.live[s.rid]
                comp.tokens.append(tok)
                comp.token_times.append(now)
                self._tok_mirror[i] = tok
                done = (s.plen + s.generated - 1 >= s.limit) or (
                    self.econ.eos_id is not None and tok == self.econ.eos_id)
                if done:
                    self._finish(i, now)
            if not self.econ.fused_sampling:
                self._writeback_sampled()
            progressed = True
        return progressed

    def drain(self) -> None:
        while self.step():
            pass

    def run(self, prompts: Sequence[Any], *, max_new_tokens: int = 16,
            temperature: float = 0.0) -> list[np.ndarray]:
        """Batch convenience: submit all, drain, return tokens in order."""
        rids = [
            self.submit(p, max_new_tokens=max_new_tokens, temperature=temperature)
            for p in prompts
        ]
        self.drain()
        return [np.asarray(self.completions[r].tokens, np.int32) for r in rids]

    @property
    def stats(self) -> dict:
        """Engine + dispatch counters (mirrors ``SynkFunction.stats``)."""
        return {**self.counters, **self.aot.stats, "executables": len(self.aot)}
