"""Continuous-batching serve engine over the slotted or paged KV cache.

The engine runs one fixed-shape decode executable over ``max_slots`` cache
lanes.  Requests are admitted into free lanes at *any* decode step (prefill
through a length-bucketed executable), finished sequences are evicted
immediately (EOS or token budget), and sampling is fused into the decode
program — the per-step host sync is a single ``(max_slots,)`` int32 token
fetch instead of a logits round-trip.

Two cache layouts (``EngineConfig.kv_layout``):

``slotted``  fixed ``max_slots x max_len`` lanes — every lane reserves
             worst-case HBM (the PR-2 baseline, kept for parity).
``paged``    a shared pool of fixed-size KV blocks with per-lane block
             tables (serve/paged.py): blocks are allocated on demand —
             prompt blocks at admission, one more each time decode
             crosses a block boundary — and freed on eviction, so
             reservation is ``num_blocks * page_size`` positions sized to
             load, not ``max_slots * max_len``.  Greedy decoding is
             token-for-token identical to the slotted layout (asserted in
             tests and gated in CI).

On the paged layout, **chunked prefill** (``EngineConfig.prefill_chunk``)
admits long prompts as fixed-size chunks processed one per engine step and
interleaved with decode, instead of one monolithic prefill call blocking
the whole batch; one AOT executable per chunk size serves every prompt.

Every executable is AOT-compiled once per static key through an
:class:`~repro.core.aot.AotCache`, so steady-state dispatch is a dict
probe: after warmup the engine's ``builds`` counter must stay flat
(asserted by ``benchmarks/serve_bench.py --smoke`` in CI, for both
layouts).

Host-side the engine keeps a mirror of the scheduling vectors (lengths,
budgets, block tables, which request owns which lane).  The mirror is
advanced by the same rules the device applies, so the engine never reads
device state back except the sampled tokens it needs to stream anyway;
block accounting is pure host bookkeeping plus a tiny ``tables`` re-push
whenever a row changes.

    engine = ServeEngine(cfg, mesh, rules, params,
                         EngineConfig(max_slots=8, max_len=256,
                                      kv_layout="paged", prefill_chunk=32))
    rid = engine.submit(prompt_ids, max_new_tokens=32, temperature=0.7,
                        top_k=50, top_p=0.9)
    engine.drain()                       # or step() under an arrival loop
    out = engine.completions[rid].tokens
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.aot import AotCache
from repro.models import registry
from repro.models.common import ShardRules
from repro.train.step import shardings_for
from .cache import bucket_for, make_slot_state, prompt_buckets, slot_state_specs, state_sds
from .paged import (
    BlockAllocator,
    SlotTables,
    blocks_for,
    cache_nbytes,
    make_paged_state,
    paged_state_specs,
)
from .step import (
    paged_decode_program,
    paged_prefill_program,
    slot_decode_program,
    slot_prefill_program,
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8            # cache lanes decoded per step
    max_len: int = 256            # max per-lane sequence length
    eos_id: int | None = None     # None: budget-only eviction
    top_k: int = 0                # default per-request top-k (0 = off)
    top_p: float = 0.0            # default per-request nucleus p (off)
    seed: int = 0
    # prompt-length buckets for the prefill executables; None -> powers of
    # two up to max_len (one AOT build per bucket ever used)
    prefill_buckets: tuple[int, ...] | None = None
    # False: benchmark baseline — logits round-trip to host sampling
    fused_sampling: bool = True
    # --- KV layout -----------------------------------------------------
    kv_layout: str = "slotted"    # "slotted" | "paged"
    page_size: int = 16           # KV block size (paged)
    # pool size in blocks incl. the null block; None -> worst case
    # (max_slots * max_len/page_size + 1) — size it below that to reserve
    # less HBM than the slotted layout
    num_blocks: int | None = None
    # >0: admit prompts in chunks of this many tokens, one chunk per
    # engine step, interleaved with decode (paged only; 0 = whole-prompt
    # bucketed prefill)
    prefill_chunk: int = 0
    paged_attn: str = "ref"       # paged decode backend: "ref" | "pallas"


@dataclasses.dataclass
class _Slot:
    rid: int
    plen: int
    limit: int                    # cache length at which the last token samples
    temperature: float
    top_k: int
    top_p: float
    prompt: np.ndarray
    chunk: int                    # prefill chunk size (== bucket when whole)
    prefilled: int = 0            # prompt positions prefilled so far
    generated: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    max_new_tokens: int
    tokens: list[int]
    token_times: list[float]      # clock() when each token reached the host
    submit_time: float
    finish_time: float


@dataclasses.dataclass
class _Pending:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float
    top_k: int
    top_p: float
    submit_time: float


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        mesh,
        rules: ShardRules,
        params,
        engine: EngineConfig = EngineConfig(),  # noqa: B008 - frozen, never mutated
        *,
        aot: AotCache | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if not registry.supports_slot_serving(cfg):
            raise ValueError(
                f"family {cfg.family!r} does not support slot serving; "
                "use serve.loop.generate_static"
            )
        if engine.kv_layout not in ("slotted", "paged"):
            raise ValueError(f"unknown kv_layout {engine.kv_layout!r}")
        self.paged = engine.kv_layout == "paged"
        if not self.paged and engine.prefill_chunk:
            raise ValueError("prefill_chunk requires kv_layout='paged'")
        if self.paged and not registry.supports_paged_serving(cfg):
            raise ValueError(
                f"family {cfg.family!r} does not support paged serving")
        self.cfg, self.mesh, self.rules = cfg, mesh, rules
        self.econ = engine
        self.buckets = tuple(engine.prefill_buckets or prompt_buckets(engine.max_len))
        if max(self.buckets) > engine.max_len:
            raise ValueError("prefill bucket exceeds max_len")
        self.aot = aot or AotCache("serve")
        self.clock = clock

        self._p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
        self._rep = NamedSharding(mesh, P())
        self.params = jax.device_put(params, self._p_sh)
        self._params_sds = registry.abstract_params(cfg)
        if self.paged:
            bs = engine.page_size
            if engine.max_len % bs:
                raise ValueError(
                    f"max_len ({engine.max_len}) must be a multiple of "
                    f"page_size ({bs})"
                )
            blocks_per_slot = engine.max_len // bs
            want = engine.num_blocks or engine.max_slots * blocks_per_slot + 1
            # round the pool up to the data-parallel size so its block dim
            # shards evenly — per-DEVICE reservation then scales down with
            # DP like the slotted cache's batch-sharded lanes does
            ndp = int(np.prod([
                mesh.shape[a] for a in ("pod", "data")
                if a in mesh.axis_names
            ]))
            self._num_blocks = -(-want // max(ndp, 1)) * max(ndp, 1)
            self.alloc = BlockAllocator(self._num_blocks, bs)
            self.tables = SlotTables(engine.max_slots, blocks_per_slot)
            self._deficit = 0           # committed-but-unallocated blocks
            self._slot_wc = [0] * engine.max_slots
            self._tables_dirty = False
            _, self._state_sh = paged_state_specs(
                cfg, mesh, engine.max_slots, engine.max_len,
                self._num_blocks, bs)
            self.state = make_paged_state(
                cfg, mesh, engine.max_slots, engine.max_len,
                self._num_blocks, bs, engine.seed)
        else:
            self._num_blocks = 0
            _, self._state_sh = slot_state_specs(
                cfg, mesh, engine.max_slots, engine.max_len)
            self.state = make_slot_state(
                cfg, mesh, engine.max_slots, engine.max_len, engine.seed)
        self._state_sds = state_sds(self.state)
        self.kv_reserved_bytes = cache_nbytes(self.state["cache"])

        self.queue: deque[_Pending] = deque()
        self.slots: list[_Slot | None] = [None] * engine.max_slots
        self.live: dict[int, Completion] = {}
        self.completions: dict[int, Completion] = {}
        self.counters = {
            "prefills": 0, "prefill_chunks": 0, "decode_steps": 0,
            "admitted": 0, "evicted": 0, "dead_slot_steps": 0,
            "kv_peak_used_bytes": 0,
        }
        self._next_rid = 0
        self._host_rng = np.random.default_rng(engine.seed)
        # host mirrors only needed when sampling is not fused
        self._tok_mirror = np.zeros(engine.max_slots, np.int32)
        self._active_mirror = np.zeros(engine.max_slots, bool)

    # ------------------------------------------------------------------
    # Executables (AOT via the shared cache)
    # ------------------------------------------------------------------
    def _sampler_key(self) -> tuple:
        e = self.econ
        return (self.cfg.name, e.max_slots, e.max_len, e.eos_id,
                e.fused_sampling, e.kv_layout, e.page_size,
                self._num_blocks, e.paged_attn)

    def _decode_exe(self):
        key = ("slot_decode",) + self._sampler_key()

        def build():
            e = self.econ
            if self.paged:
                fn = paged_decode_program(
                    self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                    fused=e.fused_sampling, impl=e.paged_attn,
                )
            else:
                fn = slot_decode_program(
                    self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                    fused=e.fused_sampling,
                )
            jitted = jax.jit(
                fn, in_shardings=(self._p_sh, self._state_sh),
                # pin state outputs to the canonical shardings so decode
                # and prefill executables hand the state back and forth
                # without resharding (AOT calls check shardings exactly)
                out_shardings=(self._state_sh, self._rep),
                donate_argnums=(1,),
            )
            return jitted.lower(self._params_sds, self._state_sds).compile()

        return self.aot.get(key, build)

    def _prefill_exe(self, bucket: int, first: bool = True):
        key = ("slot_prefill", bucket, first) + self._sampler_key()

        def build():
            e = self.econ
            rep = self._rep
            i32 = lambda shape=(): jax.ShapeDtypeStruct(shape, jnp.int32)
            f32 = lambda: jax.ShapeDtypeStruct((), jnp.float32)
            if self.paged:
                fn = paged_prefill_program(
                    self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                    fused=e.fused_sampling, first=first,
                )
                jitted = jax.jit(
                    fn,
                    in_shardings=(self._p_sh, self._state_sh) + (rep,) * 8,
                    out_shardings=(self._state_sh, rep),
                    donate_argnums=(1,),
                )
                return jitted.lower(
                    self._params_sds, self._state_sds, i32((1, bucket)),
                    i32(), i32(), i32(), i32(), f32(), i32(), f32(),
                ).compile()
            fn = slot_prefill_program(
                self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                fused=e.fused_sampling,
            )
            jitted = jax.jit(
                fn,
                in_shardings=(self._p_sh, self._state_sh) + (rep,) * 7,
                out_shardings=(self._state_sh, rep),
                donate_argnums=(1,),
            )
            return jitted.lower(
                self._params_sds, self._state_sds, i32((1, bucket)),
                i32(), i32(), i32(), f32(), i32(), f32(),
            ).compile()

        return self.aot.get(key, build)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 16,
               temperature: float = 0.0, top_k: int | None = None,
               top_p: float | None = None, rid: int | None = None) -> int:
        """Queue a request; returns its request id.  ``top_k``/``top_p``
        default to the engine-wide ``EngineConfig`` values."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        bucket_for(prompt.size, self.buckets)  # raises if it can't fit
        if prompt.size + max_new_tokens - 1 > self.econ.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.econ.max_len}"
            )
        if self.paged:
            wc = blocks_for(prompt.size + max_new_tokens - 1,
                            self.econ.page_size)
            if wc > self.alloc.capacity:
                raise ValueError(
                    f"request needs up to {wc} KV blocks but the pool only "
                    f"has {self.alloc.capacity}"
                )
        eff_k = int(self.econ.top_k if top_k is None else top_k)
        eff_p = float(self.econ.top_p if top_p is None else top_p)
        if not self.econ.fused_sampling and (eff_k > 0 or 0.0 < eff_p < 1.0):
            raise ValueError(
                "top_k/top_p require fused_sampling=True (the host-sampling "
                "ablation applies temperature only)"
            )
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.queue.append(_Pending(
            rid, prompt, max_new_tokens, float(temperature), eff_k, eff_p,
            self.clock()))
        return rid

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def _put(self, x, dtype):
        return jax.device_put(jnp.asarray(x, dtype), self._rep)

    # -- paged block bookkeeping ---------------------------------------
    def _can_admit(self, req: _Pending) -> bool:
        if not self.paged:
            return True
        wc = blocks_for(req.prompt.size + req.max_new_tokens - 1,
                        self.econ.page_size)
        # conservative: only admit when the pool can still cover every
        # live lane's worst case plus this one — decode growth can then
        # never find the free list empty
        return self.alloc.num_free - self._deficit >= wc

    def _map_blocks(self, slot: int, need: int) -> None:
        while self.tables.mapped(slot) < need:
            self.tables.append(slot, self.alloc.alloc())
            self._deficit -= 1
            self._tables_dirty = True

    def _push_tables(self) -> None:
        """Re-push the host block-table mirror as the device state leaf.
        Must run before any executable that follows a table change — in
        particular before the decode after an eviction, so stale lanes'
        sink-routed writes can't land in re-allocated blocks."""
        if self._tables_dirty:
            self.state["tables"] = self._put(self.tables.table, jnp.int32)
            self._tables_dirty = False

    # -- admission ------------------------------------------------------
    def _admit(self, req: _Pending, slot: int) -> None:
        plen = int(req.prompt.size)
        limit = plen + req.max_new_tokens - 1
        if self.paged and self.econ.prefill_chunk:
            chunk = self.econ.prefill_chunk
        else:
            chunk = bucket_for(plen, self.buckets)
        self.live[req.rid] = Completion(
            rid=req.rid, prompt_len=plen, max_new_tokens=req.max_new_tokens,
            tokens=[], token_times=[], submit_time=req.submit_time,
            finish_time=0.0,
        )
        self.slots[slot] = _Slot(
            req.rid, plen, limit, req.temperature, req.top_k, req.top_p,
            req.prompt, chunk,
        )
        if self.paged:
            wc = blocks_for(limit, self.econ.page_size)
            self._slot_wc[slot] = wc
            self._deficit += wc
        self.counters["admitted"] += 1
        self._prefill_next_chunk(slot)

    def _prefill_next_chunk(self, slot: int) -> None:
        """Run one prefill chunk for the lane (the whole bucketed prompt
        when chunking is off).  The chunk covering the prompt's last
        position samples the first token and activates the lane."""
        s = self.slots[slot]
        start = s.prefilled
        C = s.chunk
        end = min(start + C, s.plen)
        padded = np.zeros((1, C), np.int32)
        padded[0, : end - start] = s.prompt[start:end]
        if self.paged:
            self._map_blocks(slot, blocks_for(end, self.econ.page_size))
            self._push_tables()
            exe = self._prefill_exe(C, first=(start == 0))
            self.state, out = exe(
                self.params, self.state, self._put(padded, jnp.int32),
                self._put(slot, jnp.int32), self._put(start, jnp.int32),
                self._put(s.plen, jnp.int32), self._put(s.limit, jnp.int32),
                self._put(s.temperature, jnp.float32),
                self._put(s.top_k, jnp.int32), self._put(s.top_p, jnp.float32),
            )
        else:
            exe = self._prefill_exe(C)
            self.state, out = exe(
                self.params, self.state, self._put(padded, jnp.int32),
                self._put(slot, jnp.int32), self._put(s.plen, jnp.int32),
                self._put(s.limit, jnp.int32),
                self._put(s.temperature, jnp.float32),
                self._put(s.top_k, jnp.int32), self._put(s.top_p, jnp.float32),
            )
        s.prefilled = end
        self.counters["prefill_chunks"] += 1
        if end < s.plen:
            return                              # more chunks to come
        self.counters["prefills"] += 1

        if self.econ.fused_sampling:
            tok = int(np.asarray(out)[0])
        else:
            tok = int(self._host_sample(
                np.asarray(out), np.array([s.temperature]))[0])
        now = self.clock()
        comp = self.live[s.rid]
        comp.tokens.append(tok)
        comp.token_times.append(now)
        s.generated = 1
        self._tok_mirror[slot] = tok
        done = (s.plen >= s.limit) or (
            self.econ.eos_id is not None and tok == self.econ.eos_id)
        self._active_mirror[slot] = not done
        if done:
            self._finish(slot, now)
        if not self.econ.fused_sampling:
            self._writeback_sampled()

    def _finish(self, slot: int, now: float) -> None:
        s = self.slots[slot]
        comp = self.live.pop(s.rid)
        comp.finish_time = now
        self.completions[s.rid] = comp
        self.slots[slot] = None
        self._active_mirror[slot] = False
        if self.paged:
            mapped = self.tables.mapped(slot)
            self._deficit -= self._slot_wc[slot] - mapped
            self._slot_wc[slot] = 0
            for b in self.tables.release(slot):
                self.alloc.free(b)
            self._tables_dirty = True
        self.counters["evicted"] += 1

    def _host_sample(self, logits: np.ndarray, temps: np.ndarray) -> np.ndarray:
        """Benchmark baseline: sample on host from full logits (M, V)
        (temperature only — per-slot top-k/top-p ride the fused path)."""
        logits = np.asarray(logits, np.float32)
        out = np.argmax(logits, axis=-1).astype(np.int32)
        for i, t in enumerate(temps):
            if t > 0:
                z = logits[i] / t
                z -= z.max()
                p = np.exp(z)
                out[i] = self._host_rng.choice(logits.shape[-1], p=p / p.sum())
        return out

    def _writeback_sampled(self) -> None:
        """Host-sampling mode: push tokens/active back to device state."""
        self.state["tokens"] = self._put(self._tok_mirror, jnp.int32)
        self.state["active"] = self._put(self._active_mirror, jnp.bool_)

    def _note_kv_usage(self, decoding: frozenset = frozenset()) -> None:
        """Update the KV high-water mark.  Paged reads the allocator's
        monotone peak (same-step evictions can't hide it); slotted is
        sampled right after the decode write (``decoding`` = lanes whose
        new token's KV is on device but not yet in the ``generated``
        mirror) so eviction-step usage isn't under-counted."""
        if self.paged:
            used = self.alloc.peak_in_use * (
                self.kv_reserved_bytes // self._num_blocks)
        else:
            per_tok = self.kv_reserved_bytes // (
                self.econ.max_slots * self.econ.max_len)
            used = per_tok * sum(
                s.prefilled + max(0, s.generated - 1) + (i in decoding)
                for i, s in enumerate(self.slots) if s is not None
            )
        self.counters["kv_peak_used_bytes"] = max(
            self.counters["kv_peak_used_bytes"], used)

    # ------------------------------------------------------------------
    # The serving loop
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Advance in-flight chunked prefills (one chunk per lane), admit
        every queued request a free slot (and, paged, the block budget)
        can take, then advance all fully-prefilled lanes by one token.
        Returns False when idle."""
        progressed = False
        for slot, s in enumerate(self.slots):
            if s is not None and s.prefilled < s.plen:
                self._prefill_next_chunk(slot)
                progressed = True

        for slot in self.free_slots():
            if not self.queue or not self._can_admit(self.queue[0]):
                break
            self._admit(self.queue.popleft(), slot)
            progressed = True

        active_slots = [
            i for i, s in enumerate(self.slots)
            if s is not None and s.prefilled >= s.plen
        ]
        if active_slots:
            if self.paged:
                # map the block each lane's next token lands in BEFORE the
                # step — the device never allocates
                for i in active_slots:
                    s = self.slots[i]
                    next_pos = s.plen + s.generated - 1
                    self._map_blocks(
                        i, next_pos // self.econ.page_size + 1)
                self._push_tables()
            exe = self._decode_exe()
            self.state, out = exe(self.params, self.state)
            self._note_kv_usage(frozenset(active_slots))
            self.counters["decode_steps"] += 1
            self.counters["dead_slot_steps"] += (
                self.econ.max_slots - len(active_slots))
            if self.econ.fused_sampling:
                toks = np.asarray(out)          # the one per-step host sync
            else:
                temps = np.array([
                    s.temperature if s is not None else 0.0 for s in self.slots
                ])
                toks = self._host_sample(np.asarray(out), temps)
            now = self.clock()
            for i in active_slots:
                s = self.slots[i]
                tok = int(toks[i])
                s.generated += 1
                comp = self.live[s.rid]
                comp.tokens.append(tok)
                comp.token_times.append(now)
                self._tok_mirror[i] = tok
                done = (s.plen + s.generated - 1 >= s.limit) or (
                    self.econ.eos_id is not None and tok == self.econ.eos_id)
                if done:
                    self._finish(i, now)
            if not self.econ.fused_sampling:
                self._writeback_sampled()
            progressed = True
        self._note_kv_usage()
        return progressed

    def drain(self) -> None:
        while self.step():
            pass

    def run(self, prompts: Sequence[Any], *, max_new_tokens: int = 16,
            temperature: float = 0.0, top_k: int | None = None,
            top_p: float | None = None) -> list[np.ndarray]:
        """Batch convenience: submit all, drain, return tokens in order."""
        rids = [
            self.submit(p, max_new_tokens=max_new_tokens,
                        temperature=temperature, top_k=top_k, top_p=top_p)
            for p in prompts
        ]
        self.drain()
        return [np.asarray(self.completions[r].tokens, np.int32) for r in rids]

    @property
    def stats(self) -> dict:
        """Engine + dispatch counters (mirrors ``SynkFunction.stats``)."""
        return {
            **self.counters, **self.aot.stats,
            "executables": len(self.aot),
            "kv_layout": self.econ.kv_layout,
            "kv_reserved_bytes": self.kv_reserved_bytes,
        }
