"""Continuous-batching serve engine over slotted or paged per-lane state.

The engine runs one fixed-shape decode executable over ``max_slots`` cache
lanes.  Requests are admitted into free lanes at *any* decode step (prefill
through a length-bucketed executable), finished sequences are evicted
immediately (EOS or token budget), and sampling is fused into the decode
program — the per-step host sync is a single ``(max_slots,)`` int32 token
fetch instead of a logits round-trip.

"Per-lane decode state" is an abstraction, not a KV assumption
(``registry.state_kind``): the lm families carry a seq-axis KV cache,
``ssm``/xlstm carry pure per-lane recurrent state (O(1) in sequence
length — admission hard-resets a lane, eviction zeroes it), and zamba's
``hybrid`` lanes compose BOTH kinds in one cache dict (a slotted KV
segment for the shared attention block next to recurrent mamba leaves).
Admission, eviction, preempt-and-requeue, and ``prebuild()`` are
state-kind-agnostic; only the paged layout below is KV-only (recurrent
state has no seq axis to page).

Two cache layouts (``EngineConfig.kv_layout``):

``slotted``  fixed ``max_slots x max_len`` lanes — every lane reserves
             worst-case HBM (the PR-2 baseline, kept for parity).
``paged``    a shared pool of fixed-size KV blocks with per-lane block
             tables (serve/paged.py): blocks are allocated on demand —
             prompt blocks at admission, one more each time decode
             crosses a block boundary — and freed on eviction, so
             reservation is ``num_blocks * page_size`` positions sized to
             load, not ``max_slots * max_len``.  Greedy decoding is
             token-for-token identical to the slotted layout (asserted in
             tests and gated in CI).

On the paged layout, **chunked prefill** (``EngineConfig.prefill_chunk``)
admits long prompts as fixed-size chunks processed one per engine step and
interleaved with decode, instead of one monolithic prefill call blocking
the whole batch; one AOT executable per chunk size serves every prompt.

Every executable is AOT-compiled once per static key through an
:class:`~repro.core.aot.AotCache`, so steady-state dispatch is a dict
probe: after warmup the engine's ``builds`` counter must stay flat
(asserted by ``benchmarks/serve_bench.py --smoke`` in CI, for both
layouts).

Host-side the engine keeps a mirror of the scheduling vectors (lengths,
budgets, block tables, which request owns which lane).  The mirror is
advanced by the same rules the device applies, so the engine never reads
device state back except the sampled tokens it needs to stream anyway;
block accounting is pure host bookkeeping plus a tiny ``tables`` re-push
whenever a row changes.

    engine = ServeEngine(cfg, mesh, rules, params,
                         EngineConfig(max_slots=8, max_len=256,
                                      kv_layout="paged", prefill_chunk=32))
    rid = engine.submit(prompt_ids, max_new_tokens=32, temperature=0.7,
                        top_k=50, top_p=0.9)
    engine.drain()                       # or step() under an arrival loop
    out = engine.completions[rid].tokens
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from collections import deque
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.aot import AotCache
from repro.models import registry
from repro.models.attention import DecodeSharding
from repro.obs import MetricMap, Observer
from repro.models.common import ShardRules
from repro.train.step import shardings_for
from .faults import NONFINITE_TOKEN, UNCOMMITTED, FaultPlan
from .cache import (
    KeyMirror,
    RecurrentCache,
    bucket_for,
    make_slot_state,
    prompt_buckets,
    slot_state_specs,
    state_sds,
)
from .paged import (
    BlockAllocator,
    HostTier,
    LaneSpill,
    SlotTables,
    blocks_for,
    cache_nbytes,
    check_tiered,
    make_paged_state,
    paged_state_specs,
    prefix_keys,
)
from .step import (
    lane_read_program,
    lane_write_program,
    paged_block_read_program,
    paged_block_write_program,
    paged_copy_program,
    paged_decode_program,
    paged_prefill_program,
    sample_tokens,
    slot_decode_program,
    slot_prefill_program,
    spec_decode_program,
    spec_draft_prefill_program,
)


def _exact_share(total: int, units: int, denom: int) -> int:
    """``units``/``denom`` of ``total`` bytes, multiplied BEFORE dividing.

    The historical per-unit form ``(total // denom) * units`` truncates
    the per-unit share on non-divisible shapes and under-reports when
    scaled back up (by up to ``units * (denom - 1)`` bytes); multiplying
    first keeps the result the exact floor of the true fraction — and
    exact, full stop, whenever ``denom`` is an axis factor of every leaf
    (the common case for block/lane/position counts)."""
    return total * units // denom


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration.

    Fields that change a *lowered program* (shape/layout/sampler fusion)
    are folded into the AOT cache key (``ServeEngine._sampler_key``), so
    engines differing there never share executables; host-side policies
    (``prefix_cache``, ``admission``) deliberately are not — they reuse
    the same compiled programs.  Layout fields (``kv_layout`` and below)
    apply to the ``"kv"`` state kind only; recurrent/hybrid families
    serve on the slotted layout and reject paged-only options with a
    ``ValueError`` at construction.
    """

    max_slots: int = 8            # cache lanes decoded per step
    max_len: int = 256            # max per-lane sequence length
    eos_id: int | None = None     # None: budget-only eviction
    top_k: int = 0                # default per-request top-k (0 = off)
    top_p: float = 0.0            # default per-request nucleus p (off)
    seed: int = 0
    # prompt-length buckets for the prefill executables; None -> powers of
    # two up to max_len (one AOT build per bucket ever used)
    prefill_buckets: tuple[int, ...] | None = None
    # False: benchmark baseline — logits round-trip to host sampling
    fused_sampling: bool = True
    # --- KV layout -----------------------------------------------------
    kv_layout: str = "slotted"    # "slotted" | "paged"
    page_size: int = 16           # KV block size (paged)
    # pool size in blocks incl. the null block; None -> worst case
    # (max_slots * max_len/page_size + 1) — size it below that to reserve
    # less HBM than the slotted layout
    num_blocks: int | None = None
    # >0: admit prompts in chunks of this many tokens, one chunk per
    # engine step, interleaved with decode (paged only; 0 = whole-prompt
    # bucketed prefill)
    prefill_chunk: int = 0
    paged_attn: str = "ref"       # paged decode backend: "ref" | "pallas"
    # paged only: refcounted shared-prefix block reuse — submit matches a
    # new prompt against the published-block index and only prefills the
    # unmatched suffix (COW on the partial tail block)
    prefix_cache: bool = False
    # "deficit": admission gated on worst-case block commitments (decode
    # growth can never exhaust the pool).  "preempt": admit on immediate
    # need only; when growth finds the pool empty, evict the lowest-
    # priority lane back to the queue (tokens + sampling state requeued,
    # table nulled, refs dropped) — the pool runs near full
    admission: str = "deficit"
    # bounded retry budget per request: how many times a faulted lane
    # (non-finite logits, failed prefill dispatch, failed block alloc) is
    # quarantined and requeued through the preempt-and-requeue path
    # before the request goes terminal with status "failed"
    max_retries: int = 2
    # --- host-RAM tier (any layout / state kind) -----------------------
    # spill a preempted lane's state (KV blocks, or the whole-lane
    # slice registry.lane_leaf_axes describes) to host RAM and restore
    # it O(copy) at resume instead of O(generated-tokens) decode replay;
    # LRU-reclaimed prefix-cache blocks also spill (the tier is a
    # second-level prefix cache), and hold()-idle lanes can park
    # off-HBM.  Host-side policy: does NOT change executable keys beyond
    # adding the prebuilt transport programs.
    host_tier: bool = False
    # host pool budget in KV-block-sized units (paged payloads; see
    # HostTier); None = unbounded.  Ignored when the caller passes a
    # shared HostTier instance (the router does, fleet-wide).
    host_tier_blocks: int | None = None
    # a held lane parks off-HBM (lane freed, state host-resident) after
    # being held this many clock-seconds; None = held lanes stay
    # resident until release()
    park_idle_s: float | None = None
    # --- speculative decoding (any layout / state kind) ----------------
    # draft model config (an ArchConfig from models/registry): each
    # engine step the draft proposes ``spec_k`` greedy tokens per lane
    # and ONE bucketed verify executable scores all k+1 positions with
    # the target — accepted prefixes commit, the first rejection
    # resamples from the target distribution.  Greedy verification is a
    # plain argmax comparison, so the committed stream is bitwise the
    # sequential engine's (asserted by the fuzzer across every state
    # kind).  Requires fused_sampling; both fields set together.
    spec_draft: Any = None
    spec_k: int = 0


@dataclasses.dataclass
class _Slot:
    rid: int
    plen: int
    limit: int                    # cache length at which the last token samples
    temperature: float
    top_k: int
    top_p: float
    prompt: np.ndarray
    chunk: int                    # prefill chunk size (== bucket when whole)
    prefilled: int = 0            # prompt positions prefilled so far
    generated: int = 0
    pub_upto: int = 0             # leading blocks already published/matched
    emit_from: int = 0            # first k generated tokens are a replay
    #                               of already-emitted output: not re-appended
    hasher: Any = None            # incremental chain hash (prefix_keys
    hashed: int = 0               # equivalent); blocks digested so far
    deadline: float | None = None # absolute clock() time the request expires
    held: bool = False            # hold(): lane paused between user turns
    held_since: float | None = None  # clock() of the hold (park threshold)


# Terminal per-request statuses (Completion.status).  Failures are data,
# not exceptions: step() never raises for a request-level fault.  "shed"
# is produced only by the router front-end (load shedding rejects a
# request before any engine ever holds it), but it lives in the shared
# vocabulary so Completion consumers handle one status set.
STATUSES = ("ok", "timeout", "cancelled", "failed", "shed")


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    max_new_tokens: int
    tokens: list[int]
    token_times: list[float]      # clock() when each token reached the host
    submit_time: float
    finish_time: float
    # "ok" | "timeout" | "cancelled" | "failed" — non-ok completions hold
    # the tokens emitted before termination (a prefix of the fault-free
    # stream under greedy decoding)
    status: str = "ok"
    error: str | None = None      # terminal failure reason (status "failed")
    retries: int = 0              # fault retries consumed (quarantine count)


@dataclasses.dataclass
class _Pending:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float
    top_k: int
    top_p: float
    submit_time: float
    deadline: float | None = None # absolute expiry (submit_time + deadline_s)
    # preempt-and-requeue: a preempted lane requeues with its ORIGINAL
    # prompt plus the tokens already emitted (``replay``).  On
    # re-admission the prompt prefills as usual (prefill-origin KV is
    # bitwise chunk-invariant) and the generated tokens REGENERATE through
    # the decode path — decode-origin positions are only ever recomputed
    # by decode, never by prefill, so the resumed stream is bitwise the
    # original on any mesh/dtype (re-prefilling them is NOT bitwise-stable
    # under sharded bf16 reductions).  Replayed tokens are suppressed from
    # the output; a prefix-cache hit on the lane's own published chain
    # skips the replay entirely (restored mid-decode).  ``limit`` pins the
    # original budget; the live Completion is kept.
    resume: bool = False
    limit: int = 0
    replay: tuple[int, ...] = ()
    # set when the lane preempted ITSELF growing to this many blocks:
    # don't re-admit until the pool can plausibly cover that need, else
    # the same prefill chunks recompute every step until someone frees
    min_free: int = 0


class ServeEngine:
    """Continuous-batching serve engine (see the module docstring).

    Core invariants (swept by :meth:`check_invariants` and the fuzzer):

    * Slot conservation: ``admitted - evicted == len(live)`` == occupied
      lanes; a lane is owned by at most one request.
    * Paged block conservation: ``free + live + cached == capacity`` in
      the :class:`~repro.serve.paged.BlockAllocator`; every mapped block's
      refcount covers its mapping multiplicity; every written KV position
      lies inside its lane's mapped region.
    * Dispatch flatness: after :meth:`prebuild`, the AOT ``builds``
      counter never grows (CI gates ``steady_builds_delta == 0``).
    * Recurrent zeroing (non-``kv`` state kinds, fused sampling): an
      inactive lane's recurrent leaves are exactly zero after the next
      executable runs — admission hard-resets, eviction zeroes.
    * Host mirror coherence: the scheduling vectors the host keeps are
      advanced by the same rules the device applies; the only per-step
      device read is the sampled-token fetch.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        mesh,
        rules: ShardRules,
        params,
        engine: EngineConfig = EngineConfig(),  # noqa: B008 - frozen, never mutated
        *,
        aot: AotCache | None = None,
        clock: Callable[[], float] = time.perf_counter,
        faults: FaultPlan | None = None,
        obs: Observer | None = None,
        host_tier: HostTier | None = None,
        draft_params=None,
    ):
        if not registry.supports_slot_serving(cfg):
            raise ValueError(
                f"family {cfg.family!r} does not support slot serving; "
                "use serve.loop.generate_static"
            )
        if engine.kv_layout not in ("slotted", "paged"):
            raise ValueError(f"unknown kv_layout {engine.kv_layout!r}")
        self.kind = registry.state_kind(cfg)
        self.rec = RecurrentCache(cfg)
        self.paged = engine.kv_layout == "paged"
        if not self.paged and engine.prefill_chunk:
            raise ValueError("prefill_chunk requires kv_layout='paged'")
        if engine.admission not in ("deficit", "preempt"):
            raise ValueError(f"unknown admission {engine.admission!r}")
        if not self.paged and engine.prefix_cache:
            raise ValueError("prefix_cache requires kv_layout='paged'")
        if not self.paged and engine.admission != "deficit":
            raise ValueError("admission='preempt' requires kv_layout='paged'")
        if self.paged and not registry.supports_paged_serving(cfg):
            if self.kind != "kv":
                raise ValueError(
                    f"family {cfg.family!r} has state kind {self.kind!r}: "
                    "per-lane recurrent state is O(1) in sequence length — "
                    "there is no seq axis to page; use kv_layout='slotted'")
            raise ValueError(
                f"family {cfg.family!r} does not support paged serving")
        self.spec = engine.spec_draft is not None
        if self.spec != (engine.spec_k > 0):
            raise ValueError(
                "spec_draft and spec_k must be set together "
                f"(spec_draft={engine.spec_draft!r}, spec_k={engine.spec_k})")
        if self.spec:
            if not engine.fused_sampling:
                raise ValueError(
                    "speculative decoding requires fused_sampling=True "
                    "(the verify row rides the fused int32 token fetch)")
            if not registry.supports_slot_serving(engine.spec_draft):
                raise ValueError(
                    f"draft family {engine.spec_draft.family!r} does not "
                    "support slot serving")
            if engine.spec_draft.vocab != cfg.vocab:
                raise ValueError(
                    f"draft vocab {engine.spec_draft.vocab} != target "
                    f"vocab {cfg.vocab}: verify compares token ids")
        self.cfg, self.mesh, self.rules = cfg, mesh, rules
        self.econ = engine
        self.buckets = tuple(engine.prefill_buckets or prompt_buckets(engine.max_len))
        if max(self.buckets) > engine.max_len:
            raise ValueError("prefill bucket exceeds max_len")
        # Observability (repro.obs): metrics are always live (typed
        # counters behind the legacy ``self.counters`` mapping shape);
        # tracing and the flight recorder only run when the caller's
        # Observer carries them — every emit is behind an ``is not None``
        # so a default engine pays one attribute check, no host syncs,
        # and no executable-key changes.
        self.obs = obs if obs is not None else Observer(name="engine")
        self._track = self.obs.name
        # NOT ``aot or ...``: AotCache defines __len__, so a freshly made
        # (empty) shared cache is falsy and would be silently replaced —
        # every caller would then compile privately
        self.aot = aot if aot is not None else AotCache("serve", obs=self.obs)
        self.clock = clock
        # deterministic fault injection (serve/faults.py); None = off, and
        # every consult site is behind an ``is not None`` so the default
        # engine pays nothing
        self.faults = faults

        self._p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
        self._rep = NamedSharding(mesh, P())
        self.params = jax.device_put(params, self._p_sh)
        self._params_sds = registry.abstract_params(cfg)
        if self.paged:
            bs = engine.page_size
            if engine.max_len % bs:
                raise ValueError(
                    f"max_len ({engine.max_len}) must be a multiple of "
                    f"page_size ({bs})"
                )
            blocks_per_slot = engine.max_len // bs
            want = engine.num_blocks or engine.max_slots * blocks_per_slot + 1
            # round the pool up to the data-parallel size so its block dim
            # shards evenly — per-DEVICE reservation then scales down with
            # DP like the slotted cache's batch-sharded lanes does
            ndp = int(np.prod([
                mesh.shape[a] for a in ("pod", "data")
                if a in mesh.axis_names
            ]))
            self._num_blocks = -(-want // max(ndp, 1)) * max(ndp, 1)
            self.alloc = BlockAllocator(self._num_blocks, bs)
            self.tables = SlotTables(engine.max_slots, blocks_per_slot)
            self._deficit = 0           # committed-but-unallocated blocks
            self._slot_wc = [0] * engine.max_slots
            self._tables_dirty = False
            _, self._state_sh = paged_state_specs(
                cfg, mesh, engine.max_slots, engine.max_len,
                self._num_blocks, bs)
            self.state = make_paged_state(
                cfg, mesh, engine.max_slots, engine.max_len,
                self._num_blocks, bs, engine.seed)
        else:
            self._num_blocks = 0
            _, self._state_sh = slot_state_specs(
                cfg, mesh, engine.max_slots, engine.max_len)
            self.state = make_slot_state(
                cfg, mesh, engine.max_slots, engine.max_len, engine.seed)
        # --- speculative-decode draft lane state -----------------------
        # the draft cache is ALWAYS slotted (even under a paged target):
        # its per-lane state is small — max_slots x max_len worst-case for
        # a KV draft, O(1) for a recurrent one — and lives as one more
        # leaf of the engine state dict so the verify executable advances
        # target and draft in a single dispatch.  Draft state is never
        # spilled to the host tier: committed tokens fully determine it,
        # so restores rebuild it with one draft prefill over the history
        # (greedy parity is draft-independent — drafts only gate how many
        # target tokens commit per step, never their values).
        self._draft_rec = None
        self.draft_params = None
        if self.spec:
            dcfg = engine.spec_draft
            dmod = registry.get_module(dcfg)
            ddec = DecodeSharding.choose(mesh, engine.max_slots)
            dsds = dmod.make_cache_specs(dcfg, engine.max_slots,
                                         engine.max_len)
            dsh = jax.tree.map(
                lambda p: NamedSharding(mesh, p), dmod.cache_pspec(dcfg, ddec),
                is_leaf=lambda x: isinstance(x, P))
            self._state_sh["draft"] = dsh
            self.state["draft"] = jax.tree.map(
                lambda sd, d: jax.device_put(jnp.zeros(sd.shape, sd.dtype), d),
                dsds, dsh,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            self._dp_sh = shardings_for(mesh, registry.param_pspecs(dcfg, rules))
            self._dparams_sds = registry.abstract_params(dcfg)
            if draft_params is None:
                # self-contained default (router replicas need zero extra
                # plumbing): a deterministic draft init from the engine
                # seed.  Real deployments pass trained draft weights.
                draft_params = dmod.init(dcfg, jax.random.PRNGKey(engine.seed))
            self.draft_params = jax.device_put(draft_params, self._dp_sh)
            self._draft_rec = RecurrentCache(dcfg)
            # draft rebuilds cover committed HISTORIES (prompt + emitted
            # tokens), which can outgrow the largest *prompt* bucket when
            # prefill_buckets is customized below max_len
            self._spec_buckets = self.buckets \
                if max(self.buckets) >= engine.max_len \
                else self.buckets + (engine.max_len,)
        elif draft_params is not None:
            raise ValueError(
                "draft_params passed but EngineConfig.spec_draft is None")
        self._state_sds = state_sds(self.state)
        self.kv_reserved_bytes = cache_nbytes(self.state["cache"])

        # --- host-RAM tier ---------------------------------------------
        # paged engines spill per-block (the lane's KV, block by block);
        # slotted engines spill the whole-lane slice the family declares
        self._lane_axes = {} if self.paged else registry.lane_leaf_axes(cfg)
        if engine.park_idle_s is not None and not engine.host_tier:
            raise ValueError("park_idle_s requires host_tier=True")
        if engine.host_tier_blocks is not None and not engine.host_tier:
            raise ValueError("host_tier_blocks requires host_tier=True")
        if engine.host_tier:
            if not self.paged and not self._lane_axes:
                raise ValueError(
                    f"family {cfg.family!r} declares no lane_leaf_axes — "
                    "the host tier has nothing to spill on the slotted "
                    "layout")
            # a caller-provided tier is SHARED (the router passes one per
            # fleet so spills survive replica crashes)
            self.tier = host_tier if host_tier is not None \
                else HostTier(engine.host_tier_blocks)
            if self.paged and engine.prefix_cache:
                self.alloc.on_evict = self._spill_block
        else:
            if host_tier is not None:
                raise ValueError(
                    "host_tier instance passed but EngineConfig.host_tier "
                    "is False")
            self.tier = None
        # rid -> pending resume for lanes parked off-HBM by hold() +
        # park_idle_s; re-enters the queue on release()
        self.parked: dict[int, _Pending] = {}

        self.queue: deque[_Pending] = deque()
        self.slots: list[_Slot | None] = [None] * engine.max_slots
        self.live: dict[int, Completion] = {}
        self.completions: dict[int, Completion] = {}
        # the historical dict shape, now backed by typed metrics:
        # ``kv_peak_used_bytes`` is a Gauge (peak set, not a sum — see
        # _note_kv_usage); everything else is a monotone Counter.  The
        # kind split is asserted by check_invariants.
        self.counters = MetricMap(self.obs.metrics, (
            "prefills", "prefill_chunks", "decode_steps",
            "admitted", "evicted", "dead_slot_steps",
            "kv_peak_used_bytes", "prefill_tokens",
            "prefix_lookup_tokens", "prefix_hit_tokens",
            "cow_copies", "preemptions", "resumed",
            "replayed_tokens",
            # fault-tolerance lifecycle
            "status_ok", "status_timeout", "status_cancelled",
            "status_failed", "status_shed", "retries",
            "faults_injected", "faults_detected",
            "snapshot_restores",
            # per-request migration (router failover / drain)
            "exported", "imported",
            # host-RAM tier: lane spills/restores (O(copy) resume),
            # spilled prefix blocks, bytes moved each way, payloads the
            # tier refused (replay fallback), and hold/park lifecycle
            "spills", "restores", "spilled_bytes", "restored_bytes",
            "spill_drops", "prefix_spills", "host_prefix_hits",
            "holds", "releases", "parked",
            # speculative decoding: verify dispatches, lane-rounds (one
            # active lane in one verify dispatch), draft tokens
            # proposed/accepted, explicit rejections, and total committed
            # tokens (spec_committed / spec_rounds = mean committed chain
            # length per lane-round — the sequential engine is exactly
            # 1.0, so > 1.0 is the headline speedup)
            "spec_steps", "spec_rounds", "spec_drafted", "spec_accepted",
            "spec_rejected", "spec_committed",
        ), gauges=("kv_peak_used_bytes",))
        self._kv_gauge = self.obs.metrics.gauge("kv_peak_used_bytes")
        self._next_rid = 0
        # lanes barred from admission for this many more steps after a
        # fault (quarantine): the faulted occupant has already requeued,
        # and one cooldown step keeps a hot fault site from re-admitting
        # into the same lane within the same engine step
        self._quarantine = [0] * engine.max_slots
        # deadline sweep is O(queue + slots) per step; skip it entirely
        # until some request actually carries a deadline
        self._has_deadlines = False
        # host-sampling mode draws from a mirror of the device key stream
        # so it samples the same tokens as the fused path at equal seed
        self._key_mirror = KeyMirror(engine.seed)
        self._tok_mirror = np.zeros(engine.max_slots, np.int32)
        self._active_mirror = np.zeros(engine.max_slots, bool)
        self._active_dirty = False
        self._sched_dirty = False
        # lanes whose NEXT decode input is a host-forced replay token: the
        # device's done verdict is advisory there, and the recurrent
        # freeze must not zero the lane's state (see serve/step.py)
        self._replay_mirror = np.zeros(engine.max_slots, bool)
        # last engine operation ("prefill" | "decode" | "preempt") — the
        # recurrent zeroing invariant is only checkable right after a
        # decode (host-side evictions zero one executable later)
        self._last_op: str | None = None

    # ------------------------------------------------------------------
    # Executables (AOT via the shared cache)
    # ------------------------------------------------------------------
    def _sampler_key(self) -> tuple:
        e = self.econ
        return (self.cfg.name, e.max_slots, e.max_len, e.eos_id,
                e.fused_sampling, e.kv_layout, e.page_size,
                self._num_blocks, e.paged_attn,
                # spec changes the STATE SHAPE (the draft leaf), so every
                # executable — not just the verify program — keys on it
                e.spec_draft.name if e.spec_draft else None, e.spec_k)

    def _decode_exe(self):
        key = ("slot_decode",) + self._sampler_key()

        def build():
            e = self.econ
            if self.paged:
                fn = paged_decode_program(
                    self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                    fused=e.fused_sampling, impl=e.paged_attn,
                )
            else:
                fn = slot_decode_program(
                    self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                    fused=e.fused_sampling,
                )
            jitted = jax.jit(
                fn, in_shardings=(self._p_sh, self._state_sh),
                # pin state outputs to the canonical shardings so decode
                # and prefill executables hand the state back and forth
                # without resharding (AOT calls check shardings exactly)
                out_shardings=(self._state_sh, self._rep),
                donate_argnums=(1,),
            )
            return jitted.lower(self._params_sds, self._state_sds).compile()

        return self.aot.get(key, build)

    def _spec_exe(self):
        """The draft+verify speculative step (serve/step.py): k greedy
        draft proposals plus k+1 target scores per lane in ONE dispatch,
        returning a ``(max_slots, k+1)`` row matrix — still a single
        int32 fetch per engine step."""
        key = ("spec_decode",) + self._sampler_key()

        def build():
            e = self.econ
            fn = spec_decode_program(
                self.cfg, e.spec_draft, self.mesh, self.rules, k=e.spec_k,
                eos_id=e.eos_id, paged=self.paged, impl=e.paged_attn,
            )
            jitted = jax.jit(
                fn,
                in_shardings=(self._p_sh, self._dp_sh, self._state_sh),
                out_shardings=(self._state_sh, self._rep),
                donate_argnums=(2,),
            )
            return jitted.lower(self._params_sds, self._dparams_sds,
                                self._state_sds).compile()

        return self.aot.get(key, build)

    def _spec_prefill_exe(self, bucket: int):
        """Rebuild one lane's draft cache from its committed token
        history (admission, and every restore path — draft state is
        never spilled)."""
        key = ("spec_draft_prefill", bucket) + self._sampler_key()

        def build():
            rep = self._rep
            i32 = lambda shape=(): jax.ShapeDtypeStruct(shape, jnp.int32)
            fn = spec_draft_prefill_program(
                self.econ.spec_draft, self.mesh, self.rules)
            jitted = jax.jit(
                fn,
                in_shardings=(self._dp_sh, self._state_sh, rep, rep, rep),
                out_shardings=self._state_sh,
                donate_argnums=(1,),
            )
            return jitted.lower(self._dparams_sds, self._state_sds,
                                i32((1, bucket)), i32(), i32()).compile()

        return self.aot.get(key, build)

    def _spec_draft_prefill(self, slot: int, hist: np.ndarray) -> None:
        """Seed lane ``slot``'s draft cache with the committed history
        ``hist`` (prompt, or prompt + committed tokens up to — not
        including — the pending decode input).  Bucketed like the target
        prefill so the AOT cache stays flat."""
        hist = np.asarray(hist, np.int32).reshape(-1)
        bucket = bucket_for(int(hist.size), self._spec_buckets)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, : hist.size] = hist
        self.state = self._spec_prefill_exe(bucket)(
            self.draft_params, self.state, self._put(padded, jnp.int32),
            self._put(slot, jnp.int32), self._put(hist.size, jnp.int32))

    def _prefill_exe(self, bucket: int, first: bool = True):
        key = ("slot_prefill", bucket, first) + self._sampler_key()

        def build():
            e = self.econ
            rep = self._rep
            i32 = lambda shape=(): jax.ShapeDtypeStruct(shape, jnp.int32)
            f32 = lambda: jax.ShapeDtypeStruct((), jnp.float32)
            if self.paged:
                fn = paged_prefill_program(
                    self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                    fused=e.fused_sampling, first=first,
                )
                jitted = jax.jit(
                    fn,
                    in_shardings=(self._p_sh, self._state_sh) + (rep,) * 8,
                    out_shardings=(self._state_sh, rep),
                    donate_argnums=(1,),
                )
                return jitted.lower(
                    self._params_sds, self._state_sds, i32((1, bucket)),
                    i32(), i32(), i32(), i32(), f32(), i32(), f32(),
                ).compile()
            fn = slot_prefill_program(
                self.cfg, self.mesh, self.rules, eos_id=e.eos_id,
                fused=e.fused_sampling,
            )
            jitted = jax.jit(
                fn,
                in_shardings=(self._p_sh, self._state_sh) + (rep,) * 7,
                out_shardings=(self._state_sh, rep),
                donate_argnums=(1,),
            )
            return jitted.lower(
                self._params_sds, self._state_sds, i32((1, bucket)),
                i32(), i32(), i32(), f32(), i32(), f32(),
            ).compile()

        return self.aot.get(key, build)

    def _copy_exe(self):
        """Block-copy executable for the prefix cache's COW tail."""
        key = ("paged_copy",) + self._sampler_key()

        def build():
            fn = paged_copy_program(self.cfg, self.mesh, self.rules)
            i32 = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                fn,
                in_shardings=(self._state_sh, self._rep, self._rep),
                out_shardings=self._state_sh,
                donate_argnums=(0,),
            )
            return jitted.lower(self._state_sds, i32, i32).compile()

        return self.aot.get(key, build)

    # -- host-tier transport (fixed-shape, AOT like everything else) ----
    def _block_payload_sds(self) -> dict:
        out = {}
        for name, c in self._state_sds["cache"].items():
            ax = len(c.shape) - 4
            out[name] = jax.ShapeDtypeStruct(
                c.shape[:ax] + c.shape[ax + 1:], c.dtype)
        return out

    def _lane_payload_sds(self) -> dict:
        out = {}
        for name, ax in self._lane_axes.items():
            c = self._state_sds["cache"][name]
            out[name] = jax.ShapeDtypeStruct(
                c.shape[:ax] + c.shape[ax + 1:], c.dtype)
        return out

    def _block_read_exe(self):
        """Read one KV block to replicated outputs (spill fetch)."""
        key = ("tier_block_read",) + self._sampler_key()

        def build():
            fn = paged_block_read_program(self.cfg, self.mesh, self.rules)
            i32 = jax.ShapeDtypeStruct((), jnp.int32)
            out_sh = {n: self._rep for n in self._state_sds["cache"]}
            # NOT donated: the read must leave the state intact
            jitted = jax.jit(fn, in_shardings=(self._state_sh, self._rep),
                             out_shardings=out_sh)
            return jitted.lower(self._state_sds, i32).compile()

        return self.aot.get(key, build)

    def _block_write_exe(self):
        """Write one KV block from host payloads (restore)."""
        key = ("tier_block_write",) + self._sampler_key()

        def build():
            fn = paged_block_write_program(self.cfg, self.mesh, self.rules)
            i32 = jax.ShapeDtypeStruct((), jnp.int32)
            pay = self._block_payload_sds()
            pay_sh = {n: self._rep for n in pay}
            jitted = jax.jit(fn, in_shardings=(self._state_sh, pay_sh,
                                               self._rep),
                             out_shardings=self._state_sh,
                             donate_argnums=(0,))
            return jitted.lower(self._state_sds, pay, i32).compile()

        return self.aot.get(key, build)

    def _lane_read_exe(self):
        """Read one lane's whole cache slice (slotted-layout spill)."""
        key = ("tier_lane_read",) + self._sampler_key()

        def build():
            fn = lane_read_program(self.cfg, self.mesh, self.rules,
                                   axes=self._lane_axes)
            i32 = jax.ShapeDtypeStruct((), jnp.int32)
            out_sh = {n: self._rep for n in self._lane_axes}
            jitted = jax.jit(fn, in_shardings=(self._state_sh, self._rep),
                             out_shardings=out_sh)
            return jitted.lower(self._state_sds, i32).compile()

        return self.aot.get(key, build)

    def _lane_write_exe(self):
        """Write one lane's whole cache slice (slotted-layout restore)."""
        key = ("tier_lane_write",) + self._sampler_key()

        def build():
            fn = lane_write_program(self.cfg, self.mesh, self.rules,
                                    axes=self._lane_axes)
            i32 = jax.ShapeDtypeStruct((), jnp.int32)
            pay = self._lane_payload_sds()
            pay_sh = {n: self._rep for n in pay}
            jitted = jax.jit(fn, in_shardings=(self._state_sh, pay_sh,
                                               self._rep),
                             out_shardings=self._state_sh,
                             donate_argnums=(0,))
            return jitted.lower(self._state_sds, pay, i32).compile()

        return self.aot.get(key, build)

    def prebuild(self) -> None:
        """Compile every executable this engine can ever dispatch.

        Prefix hits and preemption resumes make the prefill schedule
        timing-dependent (a prompt that hit the cache in warmup may miss
        in the timed pass and vice versa), so a warmup *trace* no longer
        guarantees coverage — the bench calls this instead to keep
        ``steady_builds_delta == 0`` an invariant rather than a race.
        """
        e = self.econ
        if not self.spec:       # spec engines never dispatch plain decode
            self._decode_exe()
        chunks = (e.prefill_chunk,) if (self.paged and e.prefill_chunk) \
            else self.buckets
        for C in chunks:
            self._prefill_exe(C, first=True)
            # continuation executables: chunked prefill always, and the
            # suffix prefill of any prefix-cache hit
            if self.paged and (e.prefill_chunk or e.prefix_cache):
                self._prefill_exe(C, first=False)
        if self.paged and e.prefix_cache:
            self._copy_exe()
        if self.tier is not None:
            # spill/restore transport rides the same AOT discipline: the
            # first eviction under load must not compile
            if self.paged:
                self._block_read_exe()
                self._block_write_exe()
            else:
                self._lane_read_exe()
                self._lane_write_exe()
        if self.spec:
            self._spec_exe()
            for C in self._spec_buckets:
                self._spec_prefill_exe(C)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def validate(self, prompt, max_new_tokens: int) -> np.ndarray:
        """Admissibility checks for a request against this engine's
        config — pure config math, no engine state, so the router
        front-end can validate at its own admission boundary before any
        replica holds the request.  Returns the normalized prompt."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        bucket_for(prompt.size, self.buckets)  # raises if it can't fit
        if prompt.size + max_new_tokens - 1 > self.econ.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.econ.max_len}"
            )
        if self.paged:
            wc = blocks_for(prompt.size + max_new_tokens - 1,
                            self.econ.page_size)
            if wc > self.alloc.capacity:
                raise ValueError(
                    f"request needs up to {wc} KV blocks but the pool only "
                    f"has {self.alloc.capacity}"
                )
        return prompt

    def submit(self, prompt, *, max_new_tokens: int = 16,
               temperature: float = 0.0, top_k: int | None = None,
               top_p: float | None = None, rid: int | None = None,
               deadline_s: float | None = None) -> int:
        """Queue a request; returns its request id.  ``top_k``/``top_p``
        default to the engine-wide ``EngineConfig`` values.

        ``deadline_s`` is a per-request TTL measured from submission: a
        request still queued (or still decoding) when the deadline passes
        terminates with status ``"timeout"``, keeping whatever tokens it
        had emitted."""
        prompt = self.validate(prompt, max_new_tokens)
        eff_k = int(self.econ.top_k if top_k is None else top_k)
        eff_p = float(self.econ.top_p if top_p is None else top_p)
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be positive")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        now = self.clock()
        deadline = None if deadline_s is None else now + float(deadline_s)
        if deadline is not None:
            self._has_deadlines = True
        self.queue.append(_Pending(
            rid, prompt, max_new_tokens, float(temperature), eff_k, eff_p,
            now, deadline=deadline))
        if self.obs.tracer is not None:
            self.obs.mark("submit", rid, track=self._track,
                          plen=int(prompt.size), max_new=max_new_tokens)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is.

        Queued: removed from the queue.  Mid-decode (or mid-prefill): the
        lane is evicted — block refs drop, the deficit commitment refunds
        — exactly like a finish.  Either way the request completes with
        status ``"cancelled"`` and whatever tokens it had emitted.
        Returns False (no-op) if the request already completed; raises
        ``KeyError`` for an unknown rid."""
        if rid in self.completions:
            return False
        for idx, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[idx]
                self._terminate_queued(req, "cancelled")
                return True
        if rid in self.parked:
            self._terminate_queued(self.parked.pop(rid), "cancelled")
            return True
        for slot, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self._terminate(slot, "cancelled")
                return True
        raise KeyError(f"unknown rid {rid}")

    def _find_lane(self, rid: int) -> int | None:
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                return i
        return None

    def hold(self, rid: int) -> bool:
        """Pause a decoding lane in place (e.g. an agent turn waiting on a
        tool result): the lane keeps its slot and — for KV layouts — its
        device state, but its ``active`` bit clears so decode steps skip
        it.  Held lanes are first in line for preemption and, after
        ``park_idle_s``, are swept off HBM entirely into the host tier
        (:meth:`_park`).  Recurrent/hybrid lanes spill to the host tier
        *immediately* — the decode program zeroes inactive lanes'
        recurrent leaves, so the device copy is dead the moment the hold
        lands — which is why holding them requires a host tier.  Returns
        False if the tier refuses the snapshot (lane keeps decoding);
        idempotent for an already-held lane.  Raises ``KeyError`` for a
        rid that is not on a lane (queued/parked/completed requests can't
        be held)."""
        slot = self._find_lane(rid)
        if slot is None:
            raise KeyError(f"rid {rid} is not on a lane")
        s = self.slots[slot]
        if s.held:
            return True
        if s.prefilled < s.plen or s.generated < 1:
            raise ValueError(f"rid {rid} is mid-prefill; cannot hold")
        if self.rec:
            if not self._lane_spillable(s):
                raise ValueError(
                    "holding a recurrent/hybrid lane requires a host tier "
                    "(the freeze zeroes inactive lanes' recurrent state)")
            if not self._spill_lane(slot):
                return False
        s.held = True
        s.held_since = self.clock()
        self._active_mirror[slot] = False
        self._active_dirty = True
        self.counters["holds"] += 1
        if self.obs.tracer is not None:
            self.obs.mark("hold", rid, track=self._track, slot=slot)
        return True

    def release(self, rid: int) -> None:
        """Resume a held or parked request.  A held lane flips its
        ``active`` bit back on (recurrent lanes restore their hold-time
        snapshot from the host tier first — the device copy was zeroed);
        a parked request re-enters the queue at the front and resumes
        through the normal admission path, O(copy) if its spill survived.
        Raises ``KeyError`` if the rid is neither held nor parked."""
        if rid in self.parked:
            self.queue.appendleft(self.parked.pop(rid))
            self.counters["releases"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("release", rid, track=self._track,
                              parked=True)
            return
        slot = self._find_lane(rid)
        if slot is None or not self.slots[slot].held:
            raise KeyError(f"rid {rid} is not held or parked")
        s = self.slots[slot]
        if self.rec:
            sp = self.tier.pop_lane(rid)
            assert sp is not None and sp.kind == "lane", \
                "held recurrent lane lost its hold-time spill"
            self.state = self._lane_write_exe()(
                self.state,
                {k: self._put(v, v.dtype) for k, v in sp.leaves.items()},
                self._put(slot, jnp.int32))
            self.counters["restores"] += 1
            self.counters["restored_bytes"] += sp.nbytes
            s.held = False
            s.held_since = None
            self._active_mirror[slot] = True
            # push the whole mirror NOW: any decode before the push would
            # freeze-zero the just-written recurrent leaves, and the
            # fused sampler zeroed the held lane's ``tokens`` entry
            self._push_sched()
            self._sched_dirty = False
        else:
            s.held = False
            s.held_since = None
            self._active_mirror[slot] = True
            # the fused sampler writes 0 into inactive lanes' ``tokens``
            # leaf, so the lane's decode input token must be re-pushed
            # from the host mirror along with the active bit
            self._sched_dirty = True
        if self.spec:
            # a held lane's RECURRENT draft leaves were freeze-zeroed
            # while inactive (a KV draft survives via lazy overwrite,
            # but rebuilding unconditionally keeps one code path);
            # committed history fully determines the draft state
            comp = self.live[rid]
            self._spec_draft_prefill(slot, np.concatenate([
                s.prompt,
                np.asarray(comp.tokens[: s.generated - 1], np.int32)]))
        self.counters["releases"] += 1
        if self.obs.tracer is not None:
            self.obs.mark("release", rid, track=self._track, slot=slot)

    def _park(self, slot: int) -> None:
        """Sweep a long-held lane off HBM: spill (unless its hold-time
        snapshot already covers it), then preempt — the held routing in
        :meth:`_preempt` sends the pending to ``self.parked`` rather than
        the queue.  If the tier refuses the spill the lane parks anyway;
        :meth:`release` then resumes it via bitwise replay."""
        s = self.slots[slot]
        if not self.tier.has_lane(s.rid) and self._lane_spillable(s):
            self._spill_lane(slot)
        self._preempt(slot, spill=False)

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def has_work(self) -> bool:
        # parked requests count: they are incomplete work, just off-HBM
        # (drain() callers must release() them or they never finish)
        return bool(self.queue) or bool(self.parked) \
            or any(s is not None for s in self.slots)

    def _put(self, x, dtype):
        return jax.device_put(jnp.asarray(x, dtype), self._rep)

    # -- paged block bookkeeping ---------------------------------------
    def _can_admit(self, req: _Pending) -> bool:
        if not self.paged:
            return True
        bs = self.econ.page_size
        if self.econ.admission == "preempt":
            # immediate need only: blocks for the first prefill chunk (a
            # prefix hit can only shrink it).  Growth past that preempts.
            C = self.econ.prefill_chunk or bucket_for(
                req.prompt.size, self.buckets)
            need = max(blocks_for(min(C, int(req.prompt.size)), bs),
                       req.min_free)
            return self.alloc.available >= need
        limit = req.limit if req.resume else \
            req.prompt.size + req.max_new_tokens - 1
        wc = blocks_for(limit, bs)
        # conservative: only admit when the pool can still cover every
        # live lane's worst case plus this one — decode growth can then
        # never find the pool empty (cached blocks count: alloc reclaims
        # them, and a prefix hit that revives one also releases a unit of
        # commitment)
        return self.alloc.available - self._deficit >= wc

    def _pick_victim(self) -> int | None:
        """Lowest-priority occupied lane: held (idle) lanes first — they
        aren't decoding, so evicting one costs nothing now — then the
        highest rid (last arrived)."""
        best = None
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            if best is None or (s.held, s.rid) > (
                    self.slots[best].held, self.slots[best].rid):
                best = i
        return best

    def _alloc_block(self, slot: int) -> int | None:
        """One block for ``slot``; under ``admission='preempt'`` an empty
        pool evicts the lowest-priority lane (possibly ``slot`` itself —
        then returns None and the caller abandons the lane's step)."""
        if self.faults is not None and self.faults.fire("alloc"):
            # injected transient pool exhaustion: the requesting lane
            # retries through the same preempt-and-requeue path a real
            # fault would use, so invariants (refs, deficit) hold
            self.counters["faults_injected"] += 1
            if self.obs.tracer is not None:
                self.obs.instant("fault", track=self._track, site="alloc",
                                 rid=self.slots[slot].rid)
            self._retry_lane(slot, "injected block-alloc fault")
            return None
        while True:
            try:
                return self.alloc.alloc()
            except RuntimeError:
                if self.econ.admission != "preempt":
                    raise
                victim = self._pick_victim()
                if victim is None:
                    raise
                self._preempt(victim)
                if victim == slot:
                    return None

    def _map_blocks(self, slot: int, need: int) -> bool:
        """Grow ``slot``'s table to ``need`` blocks.  False iff the lane
        itself was preempted to find room (it no longer exists)."""
        while self.tables.mapped(slot) < need:
            b = self._alloc_block(slot)
            if b is None:
                return False
            self.tables.append(slot, b)
            if self.econ.admission == "deficit":
                self._deficit -= 1
            self._tables_dirty = True
        return True

    def preempt(self, slot: int) -> None:
        """Host-initiated preempt-and-requeue of the live lane ``slot``
        (any layout / state kind) — the hook an external priority
        scheduler uses to reclaim a lane for more urgent work.

        Same policy as the paged engine's pool-pressure preemption: the
        request requeues at the queue FRONT with its prompt, emitted
        tokens, and sampling state; resume re-prefills ONLY the prompt
        (prefill-origin state is deterministic given the same bucket
        executable — bitwise for KV *and* recurrent kinds) and replays
        the emitted tokens through decode, so the resumed stream is
        bitwise the unpreempted one (asserted for the ssm family in
        tests and the serve bench)."""
        if self.slots[slot] is None:
            raise ValueError(f"slot {slot} is not serving a request")
        self._preempt(slot)

    def _lane_spillable(self, s: _Slot) -> bool:
        """Whether a lane's decode state can move to the host tier: it
        must be fully prefilled and mid-decode (mid-prefill lanes hold
        nothing replay can't rebuild cheaper), and the layout must have a
        transport (paged blocks, or declared lane leaf axes)."""
        if self.tier is None or s.generated < 1 or s.prefilled < s.plen:
            return False
        return self.paged or bool(self._lane_axes)

    def _capture_spill(self, slot: int) -> LaneSpill:
        """Copy a lane's decode state off-device into a LaneSpill (does
        not admit it to the tier — callers do, and fall back to replay
        when the tier refuses)."""
        s = self.slots[slot]
        if self.paged:
            exe = self._block_read_exe()
            payloads = []
            for b in self.tables.blocks(slot):
                out = exe(self.state, self._put(b, jnp.int32))
                payloads.append({k: np.asarray(v) for k, v in out.items()})
            return LaneSpill(s.rid, "paged", s.prefilled, s.generated,
                             blocks=payloads)
        out = self._lane_read_exe()(self.state, self._put(slot, jnp.int32))
        leaves = {k: np.asarray(v) for k, v in out.items()}
        return LaneSpill(s.rid, "lane", s.prefilled, s.generated,
                         leaves=leaves)

    def _spill_lane(self, slot: int) -> bool:
        """Capture + admit a lane spill; counters either way."""
        sp = self._capture_spill(slot)
        if self.tier.put_lane(sp):
            self.counters["spills"] += 1
            self.counters["spilled_bytes"] += sp.nbytes
            if self.obs.tracer is not None:
                self.obs.mark("spill", sp.rid, track=self._track, slot=slot,
                              kind=sp.kind, nbytes=sp.nbytes)
            return True
        self.counters["spill_drops"] += 1
        return False

    def _spill_block(self, block: int, key: bytes | None) -> None:
        """``BlockAllocator.on_evict`` hook: an LRU-reclaimed prefix
        block's KV moves to the host tier before its device block is
        reused — the cached chain spills instead of dying, and a later
        admission (or the router's scoring) finds it via
        ``HostTier.match_chain``.  The read's host fetch blocks until the
        copy lands, so the block's new owner can't race it."""
        if key is None:
            return
        out = self._block_read_exe()(self.state, self._put(block, jnp.int32))
        payload = {k: np.asarray(v) for k, v in out.items()}
        nb = sum(a.nbytes for a in payload.values())
        if self.tier.put_block(key, payload):
            self.counters["prefix_spills"] += 1
            self.counters["spilled_bytes"] += nb
            if self.obs.tracer is not None:
                self.obs.instant("prefix_spill", track=self._track,
                                 nbytes=nb)
        else:
            self.counters["spill_drops"] += 1

    def _preempt(self, slot: int, *, spill: bool = True) -> None:
        """Evict a live lane back to the host queue: its emitted tokens
        and sampling state requeue as a resume request, the table row
        nulls (paged), and every block reference drops.  With a host
        tier the lane's decode state spills first, so the resume is an
        O(copy) restore; otherwise (or when the tier refuses, or
        ``spill=False`` — fault retries recompute rather than restore
        possibly-poisoned state) the resume replays the stream bitwise
        (see :class:`_Pending`).  A held lane's pending goes to
        ``self.parked`` instead of the queue — preempting idle work IS
        parking it early."""
        s = self.slots[slot]
        comp = self.live[s.rid]
        if spill and self._lane_spillable(s) \
                and not (s.held and self.tier.has_lane(s.rid)):
            # held recurrent lanes spilled at hold() time (the device
            # copy has been zeroed by the freeze since) — their existing
            # spill is the truth; everything else captures fresh now
            self._spill_lane(slot)
        if self.paged:
            # min_free damps re-admission until the pool can cover one
            # block MORE than the lane held — instantly re-admitting the
            # victim into the slot it just vacated would recompute the
            # same prefill chunks every step until the evictor actually
            # frees something.  Capped at the lane's worst case: mapped+1
            # on a fully-grown victim would otherwise exceed what an
            # empty pool can offer.
            wc = blocks_for(s.limit, self.econ.page_size)
            min_free = min(self.tables.mapped(slot) + 1, wc)
        else:
            min_free = 0        # slotted lanes hold no pool resources
        pending = _Pending(
            s.rid, s.prompt, comp.max_new_tokens, s.temperature, s.top_k,
            s.top_p, comp.submit_time, deadline=s.deadline, resume=True,
            limit=s.limit, replay=tuple(comp.tokens),
            min_free=0 if s.held else min_free)
        if s.held:
            # parked: off the queue until release() — min_free resets
            # because the pool pressure it damped will be long gone
            self.parked[s.rid] = pending
            self.counters["parked"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("park", s.rid, track=self._track, slot=slot)
        else:
            # resumes go to the FRONT: rid order (FCFS priority) is
            # preserved because successive victims within a step have
            # decreasing rids
            self.queue.appendleft(pending)
        self.slots[slot] = None
        self._active_mirror[slot] = False
        self._active_dirty = True
        if self.paged:
            if self.econ.admission == "deficit":
                # host-initiated preemption under deficit admission: give
                # back the lane's unallocated commitment (mapped blocks
                # free below; re-admission re-commits the worst case)
                self._deficit -= self._slot_wc[slot] - self.tables.mapped(slot)
            self._slot_wc[slot] = 0
            for b in self.tables.release(slot):
                self.alloc.free(b)
            self._tables_dirty = True
        self._last_op = "preempt"
        if not s.held:
            self.counters["preemptions"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("preempt", s.rid, track=self._track, slot=slot,
                              emitted=len(comp.tokens))

    def _push_tables(self) -> None:
        """Re-push the host block-table mirror as the device state leaf.
        Must run before any executable that follows a table change — in
        particular before the decode after an eviction, so stale lanes'
        sink-routed writes can't land in re-allocated blocks."""
        if self._tables_dirty:
            self.state["tables"] = self._put(self.tables.table, jnp.int32)
            self._tables_dirty = False

    def _push_active(self) -> None:
        """Preemption clears a lane's ``active`` bit host-side (the device
        can't know) — re-push the mirror before the next decode so the
        evicted lane stops advancing."""
        if self._active_dirty:
            self.state["active"] = self._put(self._active_mirror, jnp.bool_)
            self._active_dirty = False

    def _push_sched(self) -> None:
        """Push the whole host scheduling mirror to the device — the
        lane-restore path seeds a mid-decode lane without running any
        executable.  Values for free/mid-prefill lanes are don't-cares
        (inactive lanes are masked; prefill re-seeds its own slot), so
        rebuilding every vector from ``self.slots`` is exact."""
        n = self.econ.max_slots
        lengths = np.zeros(n, np.int32)
        limits = np.zeros(n, np.int32)
        temps = np.zeros(n, np.float32)
        tks = np.zeros(n, np.int32)
        tps = np.zeros(n, np.float32)
        for i, s in enumerate(self.slots):
            if s is None:
                self._replay_mirror[i] = False
                continue
            lengths[i] = s.prefilled if s.generated == 0 \
                else s.plen + s.generated - 1
            limits[i] = s.limit
            temps[i] = s.temperature
            tks[i] = s.top_k
            tps[i] = s.top_p
            # the NEXT decode of this lane forces a recorded replay token
            self._replay_mirror[i] = s.generated < s.emit_from
        pushes = 1
        if self.faults is not None and self.faults.fire("sched_push"):
            # injected lost push: the host mirror (not device state) is
            # the scheduling truth, so recovery is re-running the same
            # push — exercised here by pushing twice, first one "lost"
            self.counters["faults_injected"] += 1
            self.obs.instant("fault", track=self._track, site="sched_push")
            pushes = 2
        for _ in range(pushes):
            self.state["tokens"] = self._put(self._tok_mirror, jnp.int32)
            self.state["lengths"] = self._put(lengths, jnp.int32)
            self.state["limits"] = self._put(limits, jnp.int32)
            self.state["temps"] = self._put(temps, jnp.float32)
            self.state["top_ks"] = self._put(tks, jnp.int32)
            self.state["top_ps"] = self._put(tps, jnp.float32)
            self.state["replay"] = self._put(self._replay_mirror, jnp.bool_)
            self.state["active"] = self._put(self._active_mirror, jnp.bool_)
        self._active_dirty = False

    def _promote_host_chain(self, keys: list[bytes], have: int) -> int:
        """Extend a device chain match by promoting host-tier prefix
        blocks back into the device index: allocate a free block, write
        the payload, publish it under its chain key, and park it cached
        — after which the ordinary lookup/share/COW machinery treats it
        like any cached chain.  Promotion only consumes the free list; it
        never evicts device-cached blocks to make room (the two tiers
        would thrash each other).  Returns the number promoted."""
        if self.tier is None:
            return 0
        n = 0
        wexe = None
        for key in keys[have:]:
            if not self.tier.has_block(key) or self.alloc.num_free == 0:
                break
            payload = self.tier.pop_block(key)
            b = self.alloc.alloc()      # free list non-empty: no eviction
            if wexe is None:
                wexe = self._block_write_exe()
            self.state = wexe(
                self.state,
                {k: self._put(v, v.dtype) for k, v in payload.items()},
                self._put(b, jnp.int32))
            self.alloc.publish(b, key)
            self.alloc.free(b)          # parks in the cached set, indexed
            nb = sum(a.nbytes for a in payload.values())
            self.counters["host_prefix_hits"] += 1
            self.counters["restored_bytes"] += nb
            n += 1
        if n and self.obs.tracer is not None:
            self.obs.instant("host_promote", track=self._track, blocks=n)
        return n

    def _chain_lookup(self, keys: list[bytes]) -> list[int]:
        """Device chain lookup, extended through the host tier: when the
        device match ends but the tier holds the next chain blocks,
        promote them and re-match — one admission-time lookup either
        way (the counter-free ``indexed`` probe sizes the device match
        first so hit/miss stats count once per admission)."""
        if self.tier is not None:
            have = 0
            for k in keys:
                if not self.alloc.indexed(k):
                    break
                have += 1
            if have < len(keys):
                self._promote_host_chain(keys, have)
        return self.alloc.lookup(keys)

    def _try_tier_restore(self, slot: int, req: _Pending) -> bool:
        """Resume fastest path: the host tier holds the lane's spilled
        decode state — copy it back and continue mid-decode.  No
        prefill, no replay for the covered tokens, O(bytes copied), and
        bitwise identical continuation (the payload IS the evicted
        state).  A stale spill (older than the replay record — e.g. the
        restore after it was refused for pool room) restores as a
        partial resume: the tokens past its coverage replay-force
        through decode exactly like ``_try_restore``'s partial match."""
        sp = self.tier.peek_lane(req.rid) if self.tier is not None else None
        if sp is None:
            return False
        s = self.slots[slot]
        plen = int(req.prompt.size)
        k_cov = sp.generated
        if sp.prefilled != plen or not (1 <= k_cov <= len(req.replay)) \
                or sp.kind != ("paged" if self.paged else "lane"):
            # a different prompt under a recycled rid, or a layout
            # mismatch: the spill is garbage for this resume
            self.tier.drop_lane(req.rid)
            return False
        if sp.kind == "paged":
            if self.alloc.available < len(sp.blocks):
                return False    # leave the spill; this resume replays
            wexe = self._block_write_exe()
            for payload in sp.blocks:
                b = self._alloc_block(slot)
                if b is None:
                    # an injected alloc fault preempted the lane itself;
                    # its partial table was released by the preempt and
                    # _admit's slot guard abandons the admission
                    return False
                self.state = wexe(
                    self.state,
                    {k: self._put(v, v.dtype) for k, v in payload.items()},
                    self._put(b, jnp.int32))
                self.tables.append(slot, b)
                if self.econ.admission == "deficit":
                    self._deficit -= 1
                self._tables_dirty = True
            # fresh private blocks: publication state restarts (the
            # chain keys may still be indexed by the original blocks, in
            # which case publish() dedups against them)
            s.pub_upto = 0
            s.hasher = None
            s.hashed = 0
        else:
            self.state = self._lane_write_exe()(
                self.state,
                {k: self._put(v, v.dtype) for k, v in sp.leaves.items()},
                self._put(slot, jnp.int32))
        self.tier.pop_lane(req.rid)
        seq = np.concatenate([req.prompt, np.asarray(req.replay, np.int32)])
        s.prefilled = plen
        s.generated = k_cov          # next decode input is seq[plen+k_cov-1]
        self._tok_mirror[slot] = int(seq[plen + k_cov - 1])
        self._active_mirror[slot] = True
        self.counters["restores"] += 1
        self.counters["restored_bytes"] += sp.nbytes
        if sp.kind == "lane" and self.rec:
            # recurrent leaves just restored: the device must see the
            # lane active BEFORE any later executable this step, or the
            # prefill freeze zeroes them again
            self._push_sched()
            self._sched_dirty = False
        else:
            self._sched_dirty = True
        if self.spec:
            # lane spills carry only TARGET state; rebuild the draft
            # cache from the committed history the spill covers (the
            # pending input seq[plen+k_cov-1] is the next decode input,
            # so the draft's written history stops just before it)
            self._spec_draft_prefill(slot, seq[: plen + k_cov - 1])
        if self.obs.tracer is not None:
            self.obs.mark("restore", req.rid, track=self._track, slot=slot,
                          source="host_tier", kind=sp.kind, nbytes=sp.nbytes)
        return True

    def _try_restore(self, slot: int, req: _Pending) -> bool:
        """Resume fast path: if the prefix cache still holds a block chain
        covering the whole prompt (typically the lane's own published
        blocks), share it and restore the lane MID-DECODE — no prefill, no
        replay, and bitwise-original KV for every covered position.  The
        device sees the restored lane through a scheduling-vector push.
        Chains truncated by LRU reclaim re-extend from the host tier
        (:meth:`_promote_host_chain`)."""
        k = len(req.replay)
        plen = int(req.prompt.size)
        bs = self.econ.page_size
        seq = np.concatenate([req.prompt, np.asarray(req.replay, np.int32)])
        written = seq[: plen + k - 1]        # positions whose KV existed
        chain = self._chain_lookup(prefix_keys(written, bs))
        matched = len(chain) * bs
        if matched < plen:
            # prefill + decode-replay path; _match_prefix counts this
            # admission's lookup so the hit rate stays per-admission
            return False
        for b in chain:
            self.tables.append(slot, self.alloc.share(b))
            if self.econ.admission == "deficit":
                self._deficit -= 1
            self._tables_dirty = True
        s = self.slots[slot]
        s.prefilled = plen
        s.generated = matched - plen + 1     # pending input at pos ``matched``
        s.pub_upto = len(chain)
        self.counters["prefix_lookup_tokens"] += int(written.size)
        self.counters["prefix_hit_tokens"] += matched
        self._tok_mirror[slot] = int(seq[matched])
        self._active_mirror[slot] = True
        self._sched_dirty = True             # pushed before the next decode
        if self.spec:
            # the shared chain restores only TARGET KV; rebuild the
            # draft cache over the restored history (everything before
            # the pending input seq[matched])
            self._spec_draft_prefill(slot, seq[:matched])
        return True

    # -- admission ------------------------------------------------------
    def _match_prefix(self, slot: int, prompt: np.ndarray) -> int:
        """Prefix-cache lookup for a fresh lane: share the longest cached
        block chain, COW the tail block when the match would cover the
        whole prompt (the sampling position is always recomputed), and
        map everything into the lane's table.  Returns the number of
        prompt positions the cache already holds (the prefill start)."""
        bs = self.econ.page_size
        plen = int(prompt.size)
        self.counters["prefix_lookup_tokens"] += plen
        chain = self._chain_lookup(prefix_keys(prompt, bs))
        if not chain:
            return 0
        # cap the match at plen - 1: the last prompt position is always
        # recomputed (its forward pass produces the first sampled token)
        cow = len(chain) * bs >= plen
        shared = chain[:-1] if cow else chain
        for b in shared:
            self.tables.append(slot, self.alloc.share(b))
            self._tables_dirty = True
            if self.econ.admission == "deficit":
                self._deficit -= 1
        if cow:
            src = chain[-1]
            dst = self._alloc_block(slot)
            if dst is None:       # preempt mode evicted the lane itself
                return -1
            self.state = self._copy_exe()(
                self.state, self._put(src, jnp.int32),
                self._put(dst, jnp.int32))
            self.tables.append(slot, dst)
            if self.econ.admission == "deficit":
                self._deficit -= 1
            self._tables_dirty = True
            self.counters["cow_copies"] += 1
        matched = len(shared) * bs + (bs if cow else 0)
        start = plen - 1 if cow else matched
        self.counters["prefix_hit_tokens"] += start
        self.slots[slot].pub_upto = len(chain)
        return start

    def _admit(self, req: _Pending, slot: int) -> None:
        plen = int(req.prompt.size)
        limit = req.limit if req.resume else plen + req.max_new_tokens - 1
        if self.obs.tracer is not None:
            self.obs.mark("admit", req.rid, track=self._track, slot=slot,
                          resume=req.resume)
        if not req.resume:
            self.live[req.rid] = Completion(
                rid=req.rid, prompt_len=plen,
                max_new_tokens=req.max_new_tokens,
                tokens=[], token_times=[], submit_time=req.submit_time,
                finish_time=0.0,
            )
            self.counters["admitted"] += 1
        else:
            self.counters["resumed"] += 1
        self.slots[slot] = _Slot(
            req.rid, plen, limit, req.temperature, req.top_k, req.top_p,
            req.prompt, 0, emit_from=len(req.replay), deadline=req.deadline,
        )
        if self.paged and self.econ.admission == "deficit":
            wc = blocks_for(limit, self.econ.page_size)
            self._slot_wc[slot] = wc
            self._deficit += wc
        # resume restore ladder: host-tier lane spill first (full O(copy)
        # coverage of everything the lane had written when evicted), then
        # the device/host prefix chains, then prefill + decode replay
        if req.resume and req.replay and self.tier is not None \
                and self.tier.has_lane(req.rid):
            if self._try_tier_restore(slot, req):
                return
            if self.slots[slot] is None:
                return      # the lane faulted/preempted itself mid-restore
        if self.paged:
            if self.econ.prefix_cache:
                if req.resume and req.replay and self._try_restore(slot, req):
                    # restored mid-decode: nothing to prefill
                    if self.obs.tracer is not None:
                        self.obs.mark("restore", req.rid, track=self._track,
                                      slot=slot)
                    return
                start = self._match_prefix(slot, req.prompt)
                if start < 0:
                    return            # the lane preempted itself mapping COW
                self.slots[slot].prefilled = start
        s = self.slots[slot]
        if self.paged and self.econ.prefill_chunk:
            s.chunk = self.econ.prefill_chunk
        else:
            # a prefix hit prefills only the suffix: bucket THAT length so
            # short suffixes of long prompts reuse the small executables
            s.chunk = bucket_for(plen - s.prefilled, self.buckets)
        self._prefill_next_chunk(slot)

    def _publish(self, slot: int) -> None:
        """Index every newly-full block of the lane under its chain key.
        A block is publishable once the lane's written KV covers it; keys
        digest the lane's *full* token sequence (prompt + generated), so
        decode-boundary blocks are shareable too — a later prompt that
        extends this request's output (or this request resuming after a
        preemption) rides the cached chain."""
        if not self.econ.prefix_cache:
            return
        s = self.slots[slot]
        bs = self.econ.page_size
        # positions with KV written: the prefilled prompt prefix, then one
        # per decode step (the newest sampled token is not yet written)
        kv_len = s.prefilled if s.generated == 0 else s.plen + s.generated - 1
        full = kv_len // bs
        if full <= s.pub_upto:
            return
        comp = self.live[s.rid]

        def block_tokens(j: int) -> bytes:
            # tokens of logical positions [j*bs, (j+1)*bs).  comp.tokens
            # is the rid's FULL emitted history (replay included), so
            # generated position p >= plen always holds tokens[p - plen]
            a, b = j * bs, (j + 1) * bs
            parts = []
            if a < s.plen:
                parts.append(s.prompt[a: min(b, s.plen)])
            if b > s.plen:
                parts.append(np.asarray(
                    comp.tokens[max(a - s.plen, 0): b - s.plen], np.int32))
            chunk = np.concatenate(parts) if len(parts) > 1 else parts[0]
            return np.ascontiguousarray(chunk, np.int32).tobytes()

        # incremental rolling hash (byte-identical to ``prefix_keys``):
        # each block costs O(bs), not a re-hash of the whole prefix
        if s.hasher is None:
            s.hasher = hashlib.sha256()
        blocks = self.tables.blocks(slot)
        for j in range(s.pub_upto, full):
            while s.hashed <= j:
                s.hasher.update(block_tokens(s.hashed))
                s.hashed += 1
            digest = s.hasher.digest()
            self.alloc.publish(blocks[j], digest)
            if self.tier is not None and self.alloc.indexed(digest):
                # the chain key is device-indexed again: drop any host
                # copy so every key has exactly one owner (check_tiered)
                self.tier.discard_block(digest)
        s.pub_upto = full

    def _prefill_next_chunk(self, slot: int) -> None:
        """Run one prefill chunk for the lane (the whole bucketed prompt
        when chunking is off; the unmatched suffix after a prefix hit).
        The chunk covering the prompt's last position samples the first
        token and activates the lane."""
        if self.faults is not None and self.faults.fire("prefill"):
            # injected dispatch failure BEFORE the executable runs: no
            # device state advanced, the lane just requeues and retries
            self.counters["faults_injected"] += 1
            if self.obs.tracer is not None:
                self.obs.instant("fault", track=self._track, site="prefill",
                                 rid=self.slots[slot].rid)
            self._retry_lane(slot, "injected prefill-dispatch fault")
            return
        s = self.slots[slot]
        if self.obs.tracer is None:
            self._prefill_chunk_run(slot)
        else:
            with self.obs.span("prefill_chunk", track=self._track,
                               rid=s.rid, start=s.prefilled, chunk=s.chunk):
                self._prefill_chunk_run(slot)

    def _prefill_chunk_run(self, slot: int) -> None:
        s = self.slots[slot]
        start = s.prefilled
        C = s.chunk
        end = min(start + C, s.plen)
        padded = np.zeros((1, C), np.int32)
        padded[0, : end - start] = s.prompt[start:end]
        if self.paged:
            if not self._map_blocks(slot, blocks_for(end, self.econ.page_size)):
                return                          # lane preempted itself
            self._push_tables()
            exe = self._prefill_exe(C, first=(start == 0))
            self.state, out = exe(
                self.params, self.state, self._put(padded, jnp.int32),
                self._put(slot, jnp.int32), self._put(start, jnp.int32),
                self._put(s.plen, jnp.int32), self._put(s.limit, jnp.int32),
                self._put(s.temperature, jnp.float32),
                self._put(s.top_k, jnp.int32), self._put(s.top_p, jnp.float32),
            )
        else:
            exe = self._prefill_exe(C)
            self.state, out = exe(
                self.params, self.state, self._put(padded, jnp.int32),
                self._put(slot, jnp.int32), self._put(s.plen, jnp.int32),
                self._put(s.limit, jnp.int32),
                self._put(s.temperature, jnp.float32),
                self._put(s.top_k, jnp.int32), self._put(s.top_p, jnp.float32),
            )
        sub = None if self.econ.fused_sampling else self._key_mirror.split()
        s.prefilled = end
        self._last_op = "prefill"
        self.counters["prefill_chunks"] += 1
        self.counters["prefill_tokens"] += end - start
        self._publish(slot)
        if end < s.plen:
            return                              # more chunks to come
        self.counters["prefills"] += 1
        if self.spec:
            # the lane decodes from here: seed its draft cache with the
            # full prompt (draft state is never restored, always rebuilt)
            self._spec_draft_prefill(slot, s.prompt)

        if self.econ.fused_sampling:
            tok = int(np.asarray(out)[0])
        else:
            logits = np.asarray(out)
            tok = int(self._host_sample(
                logits, sub, np.array([s.temperature]),
                np.array([s.top_k]), np.array([s.top_p]))[0])
            if not np.isfinite(logits).all():
                tok = NONFINITE_TOKEN   # host-side twin of the fused sentinel
        if tok == NONFINITE_TOKEN:
            # the prompt's sampling position saw non-finite logits:
            # quarantine + bounded retry (or terminal "failed")
            self.counters["faults_detected"] += 1
            self._retry_lane(slot, "non-finite logits at prefill")
            if not self.econ.fused_sampling:
                self._writeback_sampled()
            return
        now = self.clock()
        comp = self.live[s.rid]
        s.generated = 1
        if s.emit_from >= 1:
            # replaying a preempted lane: force the RECORDED first token
            # as the next decode input.  Under greedy the regenerated
            # token equals it bitwise; under temperature>0 the regenerated
            # sample (drawn at a different key-stream position) must NOT
            # fork the conditioning away from the already-emitted history.
            # done stays False: the original run continued past here.
            self._tok_mirror[slot] = int(comp.tokens[0])
            self._active_mirror[slot] = True
            self._sched_dirty = True
            self.counters["replayed_tokens"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("replay", s.rid, track=self._track,
                              pending=s.emit_from)
        else:
            comp.tokens.append(tok)
            comp.token_times.append(now)
            if self.obs.tracer is not None:
                self.obs.mark("first_token", s.rid, track=self._track)
            self._tok_mirror[slot] = tok
            done = (s.plen >= s.limit) or (
                self.econ.eos_id is not None and tok == self.econ.eos_id)
            self._active_mirror[slot] = not done
            if done:
                self._finish(slot, now)
        if not self.econ.fused_sampling:
            self._writeback_sampled()

    def _observe_terminal(self, comp: Completion) -> None:
        """Latency histograms + the request's terminal trace mark — the
        single exit point every termination path funnels through.  The
        histogram math mirrors ``launch/serve.py``'s summary exactly:
        TTFT = first token's host arrival - submit, per-token = total
        latency / emitted tokens (requests that emitted nothing record
        no latency, matching the historical printout)."""
        st = comp.status
        if comp.tokens:
            self.obs.metrics.histogram(f"ttft_ms_{st}").observe(
                max(0.0, (comp.token_times[0] - comp.submit_time) * 1e3))
            self.obs.metrics.histogram(f"tpot_ms_{st}").observe(
                max(0.0, (comp.finish_time - comp.submit_time) * 1e3
                    / len(comp.tokens)))
        if self.obs.tracer is not None:
            self.obs.mark("terminal", comp.rid, track=self._track,
                          status=st, tokens=len(comp.tokens),
                          retries=comp.retries)

    def _finish(self, slot: int, now: float) -> None:
        # natural EOS/budget eviction: the device already deactivated the
        # lane itself, so no active-mirror push is owed
        self._terminate(slot, "ok", now=now, push_active=False)

    def _terminate(self, slot: int, status: str, *, error: str | None = None,
                   now: float | None = None,
                   push_active: bool = True) -> None:
        """Evict lane ``slot`` with a terminal ``status`` — the one
        eviction path for EOS/budget finishes ("ok"), deadline expiry
        ("timeout"), :meth:`cancel` ("cancelled"), and retry exhaustion
        ("failed").  Block refs drop and the deficit commitment refunds
        exactly as for a natural finish; host-initiated terminations
        (everything but "ok") also owe the device an active-bit push."""
        s = self.slots[slot]
        comp = self.live.pop(s.rid)
        comp.finish_time = self.clock() if now is None else now
        comp.status = status
        comp.error = error
        self.completions[s.rid] = comp
        self.slots[slot] = None
        self._active_mirror[slot] = False
        if push_active:
            self._active_dirty = True
        if self.paged:
            if self.econ.admission == "deficit":
                mapped = self.tables.mapped(slot)
                self._deficit -= self._slot_wc[slot] - mapped
                self._slot_wc[slot] = 0
            for b in self.tables.release(slot):
                self.alloc.free(b)
            self._tables_dirty = True
        if self.tier is not None:
            self.tier.drop_lane(s.rid)
        self.counters["evicted"] += 1
        self.counters[f"status_{status}"] += 1
        self._observe_terminal(comp)

    def _terminate_queued(self, req: _Pending, status: str,
                          error: str | None = None) -> None:
        """Terminal status for a request that is NOT on a lane (it holds
        no device resources).  A queued resume keeps the tokens its lane
        emitted before preemption."""
        if req.resume:
            comp = self.live.pop(req.rid)
        else:
            comp = Completion(
                rid=req.rid, prompt_len=int(req.prompt.size),
                max_new_tokens=req.max_new_tokens, tokens=[],
                token_times=[], submit_time=req.submit_time, finish_time=0.0,
            )
        comp.finish_time = self.clock()
        comp.status = status
        comp.error = error
        self.completions[req.rid] = comp
        if self.tier is not None:
            self.tier.drop_lane(req.rid)
        self.counters[f"status_{status}"] += 1
        self._observe_terminal(comp)

    def _retry_lane(self, slot: int, reason: str) -> None:
        """Quarantine + bounded retry for a faulted lane (non-finite
        logits, failed prefill dispatch, failed block alloc).  The request
        requeues through the preempt-and-requeue path — the resume
        replays its recorded tokens bitwise and reuses the existing
        bucketed executables, so retries keep ``steady_builds_delta == 0``
        — until its ``max_retries`` budget is spent; then it goes terminal
        with status "failed" (a structured result, not an exception)."""
        s = self.slots[slot]
        comp = self.live[s.rid]
        comp.retries += 1
        self.counters["retries"] += 1
        self._quarantine[slot] = 1
        if self.obs.tracer is not None:
            self.obs.mark("retry", s.rid, track=self._track, reason=reason,
                          retries=comp.retries)
        if comp.retries > self.econ.max_retries:
            self._terminate(slot, "failed", error=reason)
        else:
            # spill=False, and any earlier spill drops: a faulted lane's
            # state is suspect — the retry recomputes via prefill+replay
            # instead of restoring a possibly-poisoned copy O(fast)
            if self.tier is not None:
                self.tier.drop_lane(s.rid)
            self._preempt(slot, spill=False)

    def _expire_deadlines(self) -> None:
        """Terminate every queued or live request whose deadline passed.
        Queued requests simply leave the queue; live lanes evict with the
        full resource refund."""
        now = self.clock()
        expired = [r for r in self.queue
                   if r.deadline is not None and now >= r.deadline]
        if expired:
            dead = {r.rid for r in expired}
            self.queue = deque(r for r in self.queue if r.rid not in dead)
            for req in expired:
                self._terminate_queued(req, "timeout")
        for rid in [r for r, req in self.parked.items()
                    if req.deadline is not None and now >= req.deadline]:
            self._terminate_queued(self.parked.pop(rid), "timeout")
        for slot, s in enumerate(self.slots):
            if s is not None and s.deadline is not None \
                    and now >= s.deadline:
                self._terminate(slot, "timeout", now=now)

    def _host_sample(self, logits, sub, temps, top_ks, top_ps) -> np.ndarray:
        """Benchmark baseline: sample on host from full (M, V) logits with
        the SAME fused sampler math (temperature + per-row top-k/top-p)
        and a subkey from the device key-stream mirror — at a fixed seed
        the ablation reproduces the fused path token-for-token."""
        return np.asarray(sample_tokens(
            jnp.asarray(logits, jnp.float32), sub,
            jnp.asarray(temps, jnp.float32),
            top_ks=jnp.asarray(top_ks, jnp.int32),
            top_ps=jnp.asarray(top_ps, jnp.float32),
        ))

    def _writeback_sampled(self) -> None:
        """Host-sampling mode: push tokens/active back to device state."""
        self.state["tokens"] = self._put(self._tok_mirror, jnp.int32)
        self.state["active"] = self._put(self._active_mirror, jnp.bool_)

    def _note_kv_usage(self, decoding: frozenset = frozenset()) -> None:
        """Update the cache-usage high-water mark.  Paged reads the
        allocator's monotone peak (same-step evictions can't hide it);
        slotted KV is sampled right after the decode write (``decoding`` =
        lanes whose new token's KV is on device but not yet in the
        ``generated`` mirror) so eviction-step usage isn't under-counted.
        Recurrent/hybrid lanes cost a fixed per-lane share — their state
        is O(1) in sequence length — so usage is occupancy-proportional
        (the hybrid KV segment is folded into that per-lane constant)."""
        if self.paged:
            used = _exact_share(self.kv_reserved_bytes,
                                self.alloc.peak_in_use, self._num_blocks)
        elif self.kind == "kv":
            ntok = sum(
                s.prefilled + max(0, s.generated - 1) + (i in decoding)
                for i, s in enumerate(self.slots) if s is not None
            )
            used = _exact_share(self.kv_reserved_bytes, ntok,
                                self.econ.max_slots * self.econ.max_len)
        else:
            used = _exact_share(self.kv_reserved_bytes,
                                sum(s is not None for s in self.slots),
                                self.econ.max_slots)
        self._kv_gauge.set_max(used)

    def _advance_lane(self, i: int, tok: int, now: float) -> str:
        """Commit ONE fetched token for lane ``i`` — the per-token host
        walk shared by the plain decode step (one call per lane) and the
        speculative verify row (one call per accepted position, in row
        order).  Returns the outcome: ``"fault"`` (non-finite sentinel:
        lane quarantined + requeued, nothing committed), ``"replay"``
        (preemption replay: recorded token force-fed, nothing emitted),
        ``"done"`` (emitted and finished), or ``"ok"`` (emitted)."""
        s = self.slots[i]
        if tok == NONFINITE_TOKEN:
            # lane reported non-finite logits: its sample is invalid and
            # nothing is emitted — quarantine + bounded retry via
            # preempt-and-requeue (the resume replays the recorded
            # tokens bitwise), or terminal "failed" once the retry
            # budget is spent
            self.counters["faults_detected"] += 1
            self._retry_lane(i, "non-finite logits at decode")
            return "fault"
        s.generated += 1
        comp = self.live[s.rid]
        replaying = s.generated <= s.emit_from
        if replaying:
            # preemption replay: force the RECORDED token as the next
            # input (== the regenerated one under greedy; a stochastic
            # resample at a different key-stream position must not fork
            # the conditioning away from the emitted history).  No
            # re-emission, no done: the original run continued past
            # every replayed position.
            self._tok_mirror[i] = int(comp.tokens[s.generated - 1])
            self._sched_dirty = True
            self.counters["replayed_tokens"] += 1
        else:
            comp.tokens.append(tok)
            comp.token_times.append(now)
            self._tok_mirror[i] = tok
        if self.paged and \
                (s.plen + s.generated - 1) % self.econ.page_size == 0:
            self._publish(i)
        if replaying:
            return "replay"
        done = (s.plen + s.generated - 1 >= s.limit) or (
            self.econ.eos_id is not None and tok == self.econ.eos_id)
        if done:
            self._finish(i, now)
            return "done"
        return "ok"

    # ------------------------------------------------------------------
    # The serving loop
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Advance in-flight chunked prefills (one chunk per lane), admit
        every queued request a free slot (and, paged, the block budget)
        can take, then advance all fully-prefilled lanes by one token.
        Returns False when idle."""
        progressed = False
        if self._has_deadlines:
            self._expire_deadlines()
        if self.tier is not None and self.econ.park_idle_s is not None:
            now = self.clock()
            for slot, s in enumerate(self.slots):
                if s is not None and s.held and s.held_since is not None \
                        and now - s.held_since >= self.econ.park_idle_s:
                    self._park(slot)
                    progressed = True
        for slot in range(self.econ.max_slots):
            s = self.slots[slot]
            if s is not None and s.prefilled < s.plen:
                self._prefill_next_chunk(slot)
                progressed = True

        for slot in self.free_slots():
            if self.slots[slot] is not None:    # refilled by a resume
                continue
            if self._quarantine[slot]:
                # fault cooldown: the lane sits out exactly one admission
                # round.  Consuming the countdown counts as progress — a
                # step where every free lane is quarantined must not read
                # as "idle" to drain()
                self._quarantine[slot] -= 1
                progressed = True
                continue
            if not self.queue or not self._can_admit(self.queue[0]):
                break
            self._admit(self.queue.popleft(), slot)
            progressed = True

        def active():
            return [
                i for i, s in enumerate(self.slots)
                if s is not None and s.prefilled >= s.plen and not s.held
            ]

        active_slots = active()
        if active_slots and self.paged:
            # map the block each lane's next token lands in BEFORE the
            # step — the device never allocates.  Highest-priority lanes
            # map first, so a preemption pass evicts strictly later
            # arrivals (possibly a mapper itself, which then skips).
            for i in sorted(active_slots, key=lambda i: self.slots[i].rid):
                s = self.slots[i]
                if s is None:
                    continue                    # preempted by an earlier map
                next_pos = s.plen + s.generated - 1
                # spec: the verify row can write up to spec_k positions
                # past the next one — pre-map the whole horizon (capped
                # at the lane's budget) so rejected-step overshoot lands
                # in the lane's OWN blocks, never the write sink of an
                # unmapped entry and never a shared block (a freshly
                # mapped block is refcount-1 by construction)
                horizon = next_pos + (self.econ.spec_k if self.spec else 0)
                horizon = min(horizon, s.limit - 1)
                self._map_blocks(i, horizon // self.econ.page_size + 1)
            self._push_tables()
            active_slots = active()
        if active_slots:
            if self._sched_dirty:
                # lane restore or replay forcing rewrote the scheduling
                # mirror (tokens/active/lengths) — push it whole before
                # the device advances
                self._push_sched()
                self._sched_dirty = False
            else:
                self._push_active()
            # the decode span covers dispatch AND the token fetch — the
            # one per-step host sync — so its duration is the real
            # step-critical path, measured by the engine's own clock
            sid = None if self.obs.tracer is None else self.obs.begin(
                "decode", track=self._track, lanes=len(active_slots))
            if self.spec:
                self.state, out = self._spec_exe()(
                    self.params, self.draft_params, self.state)
            else:
                exe = self._decode_exe()
                self.state, out = exe(self.params, self.state)
            self._last_op = "decode"
            sub = None if self.econ.fused_sampling \
                else self._key_mirror.split()
            self._note_kv_usage(frozenset(active_slots))
            self.counters["decode_steps"] += 1
            self.counters["dead_slot_steps"] += (
                self.econ.max_slots - len(active_slots))
            if self.spec:
                # (max_slots, k+1) verify rows — still ONE int32 fetch
                rows = np.asarray(out)
                self.counters["spec_steps"] += 1
                self.counters["spec_rounds"] += len(active_slots)
            elif self.econ.fused_sampling:
                toks = np.asarray(out)          # the one per-step host sync
            else:
                arr = lambda f, d, dt: np.array([
                    f(s) if s is not None else d for s in self.slots
                ], dtype=dt)
                logits = np.asarray(out)
                toks = self._host_sample(
                    logits, sub,
                    arr(lambda s: s.temperature, 0.0, np.float32),
                    arr(lambda s: s.top_k, 0, np.int32),
                    arr(lambda s: s.top_p, 0.0, np.float32))
                toks = np.where(
                    np.isfinite(logits).all(axis=-1), toks,
                    np.int32(NONFINITE_TOKEN))  # host twin of the sentinel
            self.obs.end(sid)
            if self.faults is not None:
                lane = self.faults.pick("decode_logits", active_slots)
                if lane is not None:
                    # simulate the device having detected non-finite
                    # logits for this lane: flip its word in the fetched
                    # vector to the sentinel the real detector reports
                    self.counters["faults_injected"] += 1
                    if self.obs.tracer is not None:
                        self.obs.instant(
                            "fault", track=self._track, site="decode_logits",
                            rid=self.slots[lane].rid)
                    if self.spec:
                        rows = np.array(rows, copy=True)
                        rows[lane, 0] = NONFINITE_TOKEN
                    else:
                        toks = np.array(toks, copy=True)
                        toks[lane] = NONFINITE_TOKEN
            now = self.clock()
            if self.spec:
                for i in active_slots:
                    s = self.slots[i]
                    # accounting is per-SPECULATION: replay rounds force
                    # one recorded token and speculate nothing
                    replaying0 = s.generated < s.emit_from
                    c = 0
                    outcome = "ok"
                    for tok in rows[i]:
                        tok = int(tok)
                        if tok == UNCOMMITTED:
                            break       # first rejected/inactive position
                        outcome = self._advance_lane(i, tok, now)
                        if outcome == "fault":
                            break
                        c += 1
                        if outcome == "done":
                            break
                    self.counters["spec_committed"] += c
                    if not replaying0:
                        self.counters["spec_drafted"] += self.econ.spec_k
                        if c:
                            # the row's first commit scores the pending
                            # token (not a draft); commits 2..c each
                            # accept one draft proposal
                            self.counters["spec_accepted"] += c - 1
                        if outcome == "ok" and c <= self.econ.spec_k:
                            # the chain ended by draft mismatch (not by
                            # finishing, faulting, or running out of row)
                            self.counters["spec_rejected"] += 1
            else:
                for i in active_slots:
                    self._advance_lane(i, int(toks[i]), now)
            if not self.econ.fused_sampling:
                self._writeback_sampled()
            progressed = True
        self._note_kv_usage()
        return progressed

    def drain(self) -> None:
        while self.step():
            pass

    def run(self, prompts: Sequence[Any], *, max_new_tokens: int = 16,
            temperature: float = 0.0, top_k: int | None = None,
            top_p: float | None = None) -> list[np.ndarray]:
        """Batch convenience: submit all, drain, return tokens in order."""
        rids = [
            self.submit(p, max_new_tokens=max_new_tokens,
                        temperature=temperature, top_k=top_k, top_p=top_p)
            for p in prompts
        ]
        self.drain()
        return [np.asarray(self.completions[r].tokens, np.int32) for r in rids]

    # ------------------------------------------------------------------
    # Crash-consistent snapshot / restore
    # ------------------------------------------------------------------
    # The engine's durable truth is entirely host-side: the queue, the
    # per-request Completions, and the recorded token streams.  Device
    # state (KV pool contents, block tables, the prefix index over pool
    # blocks) is a CACHE of that truth — a live lane's KV is recomputable
    # from its prompt + recorded tokens through the same preempt-resume
    # path the engine already uses under pool pressure.  A snapshot
    # therefore serializes every live lane as a front-of-queue resume
    # request and drops the allocator/prefix index (the pool it describes
    # died with the process); restore into a FRESH engine re-prefills and
    # replays, which is bitwise the uninterrupted stream under greedy
    # decoding (the PR-4 replay property).  Everything in the snapshot is
    # plain JSON, so it rides CheckpointManager's atomic meta.json.

    _SNAP_FORMAT = 1

    @staticmethod
    def _snap_pending(req: _Pending) -> dict:
        return {
            "rid": req.rid,
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": req.max_new_tokens,
            "temperature": req.temperature,
            "top_k": req.top_k,
            "top_p": req.top_p,
            "submit_time": req.submit_time,
            "deadline": req.deadline,
            "resume": req.resume,
            "limit": req.limit,
            "replay": [int(t) for t in req.replay],
        }

    @staticmethod
    def _snap_completion(comp: Completion) -> dict:
        return {
            "rid": comp.rid,
            "prompt_len": comp.prompt_len,
            "max_new_tokens": comp.max_new_tokens,
            "tokens": [int(t) for t in comp.tokens],
            "token_times": [float(t) for t in comp.token_times],
            "submit_time": comp.submit_time,
            "finish_time": comp.finish_time,
            "status": comp.status,
            "error": comp.error,
            "retries": comp.retries,
        }

    @staticmethod
    def _load_completion(d: dict) -> Completion:
        return Completion(
            rid=int(d["rid"]), prompt_len=int(d["prompt_len"]),
            max_new_tokens=int(d["max_new_tokens"]),
            tokens=[int(t) for t in d["tokens"]],
            token_times=[float(t) for t in d["token_times"]],
            submit_time=float(d["submit_time"]),
            finish_time=float(d["finish_time"]),
            status=d["status"], error=d["error"], retries=int(d["retries"]),
        )

    def _econ_json(self) -> dict:
        # JSON round-trip normalization (tuples -> lists) so a snapshot
        # read back from disk compares equal to a live config
        return json.loads(json.dumps(dataclasses.asdict(self.econ)))

    def snapshot(self) -> dict:
        """Serialize the engine's host-side truth as a JSON-able dict.

        Live lanes become front-of-queue resume requests (rid order =
        FCFS priority), exactly as :meth:`preempt` would requeue them;
        the queued tail follows unchanged.  Device caches are dropped —
        see the section comment.  Consistent at any step boundary."""
        on_lane = sorted(
            (i for i, s in enumerate(self.slots) if s is not None),
            key=lambda i: self.slots[i].rid)
        pend = []
        for slot in on_lane:
            s = self.slots[slot]
            comp = self.live[s.rid]
            pend.append(self._snap_pending(_Pending(
                s.rid, s.prompt, comp.max_new_tokens, s.temperature,
                s.top_k, s.top_p, comp.submit_time, deadline=s.deadline,
                resume=True, limit=s.limit, replay=tuple(comp.tokens))))
        pend.extend(self._snap_pending(req) for req in self.queue)
        # parked requests resume DECODING after a restart: hold is
        # scheduling state, not durable truth — the restarted engine
        # requeues them like any preempted resume
        pend.extend(self._snap_pending(self.parked[r])
                    for r in sorted(self.parked))
        return {
            "format": self._SNAP_FORMAT,
            "arch": self.cfg.name,
            "engine": self._econ_json(),
            "queue": pend,
            # Completions of every in-flight rid (lane occupants and
            # queued resumes) — restore re-links them so replay forcing
            # and result continuity work across the restart
            "live": {str(r): self._snap_completion(c)
                     for r, c in self.live.items()},
            "completions": {str(r): self._snap_completion(c)
                            for r, c in self.completions.items()},
            "counters": dict(self.counters),
            "next_rid": self._next_rid,
        }

    def restore(self, snap: dict) -> None:
        """Rebuild serving state from :meth:`snapshot` into THIS engine,
        which must be freshly constructed (same arch + ``EngineConfig``)
        and never have served a request — the snapshot's device caches
        are gone, so restore re-derives them by re-prefilling prompts and
        replaying recorded tokens (bitwise the original stream under
        greedy decoding).  Drive with :meth:`step`/:meth:`drain` as
        usual afterwards."""
        if int(snap.get("format", -1)) != self._SNAP_FORMAT:
            raise ValueError(
                f"unsupported snapshot format {snap.get('format')!r}")
        if snap["arch"] != self.cfg.name:
            raise ValueError(
                f"snapshot is for arch {snap['arch']!r}, engine is "
                f"{self.cfg.name!r}")
        if snap["engine"] != self._econ_json():
            raise ValueError(
                "snapshot EngineConfig does not match this engine's")
        if self.has_work() or self.live or self.completions \
                or self.counters["admitted"]:
            raise ValueError("restore() requires a fresh engine")
        for req in snap["queue"]:
            deadline = req["deadline"]
            if deadline is not None:
                self._has_deadlines = True
            # min_free deliberately resets to 0: it damped re-admission
            # against the OLD engine's pool pressure, which died with it
            self.queue.append(_Pending(
                int(req["rid"]), np.asarray(req["prompt"], np.int32),
                int(req["max_new_tokens"]), float(req["temperature"]),
                int(req["top_k"]), float(req["top_p"]),
                float(req["submit_time"]), deadline=deadline,
                resume=bool(req["resume"]), limit=int(req["limit"]),
                replay=tuple(int(t) for t in req["replay"])))
        self.live = {int(r): self._load_completion(c)
                     for r, c in snap["live"].items()}
        self.completions = {int(r): self._load_completion(c)
                            for r, c in snap["completions"].items()}
        self.counters.update(snap["counters"])
        self._next_rid = int(snap["next_rid"])
        self.counters["snapshot_restores"] += 1
        self.obs.instant("snapshot_restore", track=self._track,
                         queued=len(self.queue), live=len(self.live))

    # -- per-request migration (router failover / drain) ---------------
    def export_request(self, rid: int) -> dict:
        """Remove one in-flight request from THIS engine and serialize it
        for migration to another replica (the router's drain path).

        A lane occupant is first preempted — migration IS a preemption,
        just resumed elsewhere: blocks free, the deficit refunds, and the
        emitted tokens ride along as the replay.  The returned dict is
        JSON-able, shaped like one entry of :meth:`snapshot`:
        ``{"pending": ..., "completion": ... | None}`` (the live
        Completion travels with a resume so replay forcing and result
        continuity survive the move).  Raises ``KeyError`` for unknown
        rids and ``ValueError`` for already-terminal ones."""
        if rid in self.completions:
            raise ValueError(f"rid {rid} is already terminal")
        for slot, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self._preempt(slot)     # now front-of-queue, resume=True
                break
        if rid in self.parked:
            # pre-parked, or a held lane the preempt above just parked —
            # either way it migrates like any resume.  Its host-tier
            # spill stays behind: with a router-shared tier the importer
            # restores O(copy); otherwise the resume replays.
            req = self.parked.pop(rid)
            comp = self.live.pop(rid, None)
            self.counters["exported"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("export", rid, track=self._track, resume=True)
            return {
                "pending": self._snap_pending(req),
                "completion":
                    None if comp is None else self._snap_completion(comp),
            }
        for idx, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[idx]
                comp = self.live.pop(rid, None) if req.resume else None
                self.counters["exported"] += 1
                if self.obs.tracer is not None:
                    self.obs.mark("export", rid, track=self._track,
                                  resume=req.resume)
                return {
                    "pending": self._snap_pending(req),
                    "completion":
                        None if comp is None else self._snap_completion(comp),
                }
        raise KeyError(f"unknown rid {rid}")

    def import_request(self, snap: dict, *, front: bool = False) -> int:
        """Install a request exported from another replica (or rebuilt by
        the router from its own stream mirror after a crash) into this
        engine's queue.  ``front=True`` preserves the resume-first FCFS
        priority a preemption would have had.  The request then admits,
        re-prefills, and replays through the ordinary resume path —
        bitwise the uninterrupted stream under greedy decoding.

        Unlike :meth:`restore` this composes with a BUSY engine: rid
        uniqueness is checked against everything this engine knows."""
        req = snap["pending"]
        rid = int(req["rid"])
        if (rid in self.live or rid in self.completions
                or rid in self.parked
                or any(r.rid == rid for r in self.queue)):
            raise ValueError(f"rid {rid} already known to this engine")
        resume = bool(req["resume"])
        comp = snap.get("completion")
        if resume and comp is None:
            raise ValueError(f"resume import of rid {rid} without its "
                             "live Completion")
        prompt = self.validate(np.asarray(req["prompt"], np.int32),
                               int(req["max_new_tokens"]))
        deadline = req["deadline"]
        if deadline is not None:
            self._has_deadlines = True
        # min_free resets to 0: it damped re-admission against the OLD
        # replica's pool pressure, which stayed behind with it
        pending = _Pending(
            rid, prompt, int(req["max_new_tokens"]),
            float(req["temperature"]), int(req["top_k"]),
            float(req["top_p"]), float(req["submit_time"]),
            deadline=deadline, resume=resume, limit=int(req["limit"]),
            replay=tuple(int(t) for t in req["replay"]))
        if resume:
            self.live[rid] = self._load_completion(comp)
        (self.queue.appendleft if front else self.queue.append)(pending)
        self._next_rid = max(self._next_rid, rid + 1)
        self.counters["imported"] += 1
        if self.obs.tracer is not None:
            self.obs.mark("import", rid, track=self._track, resume=resume,
                          front=front)
        return rid

    def save_snapshot(self, mgr, step: int) -> None:
        """Persist :meth:`snapshot` through a
        :class:`~repro.checkpoint.manager.CheckpointManager` (atomic
        tmp-then-rename write; a crash mid-save leaves the previous
        checkpoint restorable)."""
        mgr.save(step, {}, extra_meta={"engine_snapshot": self.snapshot()})

    def restore_snapshot(self, mgr, step: int | None = None) -> int:
        """Restore from the checkpoint written by :meth:`save_snapshot`
        (latest when ``step`` is None).  Returns the checkpoint step."""
        step, meta = mgr.load_meta(step)
        if "engine_snapshot" not in meta:
            raise KeyError(f"checkpoint step {step} has no engine snapshot")
        self.restore(meta["engine_snapshot"])
        return step

    # ------------------------------------------------------------------
    # Invariants + stats
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Conservation sweep — the fuzz harness runs this after every
        step.  Paged engines: free + live + cached partitions the pool,
        refcounts cover every mapping, every lane's written KV lies
        inside its mapped region (so no write can ever route to the null
        block while live), and deficit admission never over-commits.
        Recurrent/hybrid engines: every unoccupied lane's recurrent
        leaves are exactly zero (evict-time zeroing), checked when the
        last executable was a decode step — host-side evictions between
        executables (preemption, instant-finish prefills) zero one
        executable later.  Lifecycle: every completion carries a terminal
        status accounted in the status counters, and every in-flight
        Completion is owned by exactly one lane or one queued resume.

        A failed sweep dumps the flight recorder (when one is attached)
        before re-raising, so the event history leading up to the trip
        lands on disk with the assertion message."""
        try:
            self._check_invariants()
        except AssertionError as e:
            self.obs.record("invariant_failure", engine=self._track,
                            error=str(e))
            self.obs.dump("engine_invariant_failure", context={
                "engine": self._track,
                "error": str(e),
                "live_rids": sorted(self.live),
                "queued_rids": [r.rid for r in self.queue],
                "counters": dict(self.counters),
            })
            raise

    def _check_invariants(self) -> None:
        # metric-kind hygiene: the peak gauge must never have become a
        # counter (or vice versa) behind the MetricMap facade
        self.obs.metrics.check()
        kind = self.obs.metrics.kind
        assert kind("kv_peak_used_bytes") == "gauge", \
            "kv_peak_used_bytes must be a gauge (peak set, not a sum)"
        for k in ("decode_steps", "admitted", "evicted", "preemptions"):
            assert kind(k) == "counter", f"{k} must be a counter"
        for comp in self.completions.values():
            assert comp.status in STATUSES, (
                f"rid {comp.rid}: unknown status {comp.status!r}")
        assert sum(self.counters[f"status_{st}"] for st in STATUSES) \
            == len(self.completions), "status counters != completions"
        inflight = sorted(
            [s.rid for s in self.slots if s is not None]
            + [r.rid for r in self.queue if r.resume]
            + list(self.parked))
        assert inflight == sorted(self.live), (
            f"live rids {sorted(self.live)} != lane/resume/parked rids "
            f"{inflight}")
        for rid, req in self.parked.items():
            assert req.resume and req.rid == rid, (
                f"parked rid {rid} is not a resume pending")
        for slot, q in enumerate(self._quarantine):
            assert 0 <= q <= 1, f"slot {slot}: quarantine {q} out of range"
        if self.rec and self.econ.fused_sampling \
                and self._last_op == "decode":
            free = [i for i, s in enumerate(self.slots) if s is None]
            assert self.rec.lanes_are_zero(self.state["cache"], free), (
                f"an evicted lane in {free} holds non-zero recurrent state")
        if self.spec and self._draft_rec and self._last_op == "decode":
            # the spec program's draft-side freeze is the only thing
            # zeroing dead draft lanes — sweep it like the target's
            free = [i for i, s in enumerate(self.slots) if s is None]
            assert self._draft_rec.lanes_are_zero(self.state["draft"], free), (
                f"an evicted lane in {free} holds non-zero DRAFT "
                "recurrent state")
        if not self.paged:
            if self.tier is not None:
                self.tier.check()
            return
        if self.tier is not None:
            check_tiered(self.alloc, self.tier)
        else:
            self.alloc.check()
        shared = self.econ.prefix_cache
        self.tables.check(refcount=self.alloc.refcount if shared else None)
        bs = self.econ.page_size
        for i, s in enumerate(self.slots):
            if s is None:
                assert self.tables.mapped(i) == 0, f"freed slot {i} maps blocks"
                continue
            kv_len = s.prefilled if s.generated == 0 \
                else s.plen + s.generated - 1
            assert kv_len <= self.tables.mapped(i) * bs, (
                f"slot {i}: {kv_len} KV positions written but only "
                f"{self.tables.mapped(i)} blocks mapped")
            for j, b in enumerate(self.tables.blocks(i)):
                assert self.alloc.refcount(b) >= 1, (
                    f"slot {i} maps non-live block {b}")
                if (j + 1) * bs > kv_len:
                    # no mapped block extending past the lane's committed
                    # KV may be shared: publication only ever indexes
                    # FULL blocks ((j+1)*bs <= kv_len at publish time),
                    # so any write past the commit point — a plain decode
                    # write, or spec verify overshoot on rejected steps —
                    # can only land in a block this lane owns outright
                    assert self.alloc.refcount(b) == 1, (
                        f"slot {i}: block {b} covers positions past "
                        f"kv_len {kv_len} but is shared "
                        f"(refcount {self.alloc.refcount(b)})")
        if self.econ.admission == "deficit":
            assert self.alloc.available >= self._deficit >= 0, (
                f"deficit {self._deficit} exceeds available "
                f"{self.alloc.available}")

    @property
    def stats(self) -> dict:
        """Engine + dispatch counters (mirrors ``SynkFunction.stats``)."""
        out = {
            **self.counters, **self.aot.stats,
            "executables": len(self.aot),
            "kv_layout": self.econ.kv_layout,
            "state_kind": self.kind,
            "kv_reserved_bytes": self.kv_reserved_bytes,
        }
        if self.spec:
            drafted = self.counters["spec_drafted"]
            out["spec_acceptance_rate"] = (
                self.counters["spec_accepted"] / drafted if drafted else 0.0)
            # mean committed chain length per lane per verify dispatch;
            # the sequential engine commits exactly 1.0 per lane-round,
            # so anything above 1.0 is speculation paying for itself
            rounds = self.counters["spec_rounds"]
            out["tokens_per_decode_dispatch"] = (
                self.counters["spec_committed"] / rounds if rounds else 0.0)
        if self.paged:
            out["prefix_cached_blocks"] = self.alloc.num_cached
            out["prefix_cache_evictions"] = self.alloc.cache_evictions
            looked = self.counters["prefix_lookup_tokens"]
            out["prefix_hit_rate"] = (
                self.counters["prefix_hit_tokens"] / looked if looked else 0.0)
        if self.tier is not None:
            out["host_tier"] = {
                "spilled_lanes": self.tier.spilled_lanes,
                "spilled_blocks": self.tier.spilled_blocks,
                "used_bytes": self.tier.used_bytes,
                "capacity_blocks": self.tier.capacity_blocks,
                "lane_spills": self.tier.lane_spills,
                "lane_restores": self.tier.lane_restores,
                "prefix_spills": self.tier.prefix_spills,
                "prefix_hits": self.tier.prefix_hits,
                "drops": self.tier.drops,
            }
        if self.faults is not None:
            out["faults"] = self.faults.stats()
        return out
