"""Deterministic fault injection for the serve engine.

A :class:`FaultPlan` is a seeded schedule of failures the engine consults
at named sites (``FAULT_SITES``).  The default engine runs with no plan at
all (``faults=None``) — every consult site is behind a ``is not None``
check, so fault injection is zero-cost when off — and a given ``(seed,
rates)`` plan replays the same schedule on every run: each site draws
from its own ``numpy`` Generator seeded from ``(seed, site)``, so the
fire/skip sequence depends only on the engine's (deterministic) consult
order, never on wall clock or interleaving with other sites.  Chaos-fuzz
failures are therefore reproducible by seed number, exactly like the
parity fuzzer's request streams.

Sites:

``decode_logits``  corrupt one active lane's post-decode token fetch to
                   the :data:`NONFINITE_TOKEN` sentinel — what the device
                   reports when a lane's logits contain NaN/Inf.  Drives
                   the quarantine + bounded-retry path.
``prefill``        fail a prefill-chunk dispatch before it runs; the lane
                   retries through preempt-and-requeue.
``alloc``          fail a KV block allocation (transient pool
                   exhaustion); the requesting lane retries.
``sched_push``     lose a host->device scheduling push; the host mirror
                   is the source of truth, so recovery is an idempotent
                   re-push of the same vectors.
``replica_crash``  (router-level) one engine replica dies outright: its
                   host state is gone and the router fails its in-flight
                   requests over to survivors from its own stream
                   mirrors.
``replica_stall``  (router-level) one replica hangs without dying; the
                   router's step-budget health check detects the missing
                   progress and fails it over like a crash.

The engine consults the first four sites; the router front-end
(``serve/router.py``) consults the two ``replica_*`` sites.  Victim
selection (``pick``) draws from a separate ``(seed, site, victim)``
substream, so whether a consult fires perturbs neither later fires at
that site nor any other site's schedule — the fire/skip sequence depends
only on consult order.

The engine's recovery machinery is shared with normal operation (the
PR-4/5 preempt-and-requeue path), so every executable a retry dispatches
is already in the AOT cache — chaos runs keep ``steady_builds_delta == 0``.
"""
from __future__ import annotations

import numpy as np

from repro.obs.metrics import MetricMap, MetricsRegistry

ENGINE_FAULT_SITES = ("decode_logits", "prefill", "alloc", "sched_push")
REPLICA_FAULT_SITES = ("replica_crash", "replica_stall")
FAULT_SITES = ENGINE_FAULT_SITES + REPLICA_FAULT_SITES

# Spawn-key tag distinguishing the victim-selection substream from the
# fire/skip stream at the same site.
_VICTIM_STREAM = 1

# Sentinel token value the decode/prefill executables report for a lane
# whose logits contain a non-finite value (vocab ids are >= 0, so the
# sentinel rides the existing (max_slots,) int32 token fetch — no extra
# host sync).  The host treats it as "this lane's sample is invalid":
# quarantine the lane and retry the request, or fail it terminally.
NONFINITE_TOKEN = -1

# Sentinel for speculative-decode verify rows: entries past a lane's
# accepted prefix (the draft diverged, the lane was inactive, or the lane
# finished earlier in the row).  Rides the same int32 fetch as the tokens
# themselves — the host stops committing a lane's row at the first
# UNCOMMITTED entry.  Distinct from NONFINITE_TOKEN, which marks a
# *committed* position whose logits were non-finite (quarantine path).
UNCOMMITTED = -2


class FaultPlan:
    """Seeded per-site fault schedule.

    ``rates`` maps site name -> per-consult fire probability (sites not
    named never fire).  ``max_fires`` bounds the total number of fires
    across all sites (None = unbounded); the draw stream still advances
    past the budget so truncating it never re-times later consults.

    A plan is mutable (rng positions + counters): use a fresh instance
    per engine run, and the same seed to reproduce a run.
    """

    def __init__(self, seed: int, rates: dict[str, float] | None = None,
                 *, max_fires: int | None = None):
        rates = dict(rates or {})
        unknown = set(rates) - set(FAULT_SITES)
        if unknown:
            raise ValueError(
                f"unknown fault sites {sorted(unknown)}; "
                f"valid sites: {FAULT_SITES}")
        self.seed = int(seed)
        self.rates = {s: float(rates.get(s, 0.0)) for s in FAULT_SITES}
        self.max_fires = max_fires
        self._rng = {
            s: np.random.default_rng([self.seed, i])
            for i, s in enumerate(FAULT_SITES)
        }
        # Victim selection lives in its own per-site substream: a pick()
        # consult that fires must not advance the fire/skip stream by a
        # different amount than one that skips, or every later fire at
        # the site would re-time based on *outcomes* instead of consult
        # order (and rate changes would desynchronize the schedule).
        self._victim_rng = {
            s: np.random.default_rng([self.seed, i, _VICTIM_STREAM])
            for i, s in enumerate(FAULT_SITES)
        }
        # per-site consult/fire counts are typed counters (repro.obs) so
        # the chaos bench's metrics snapshot carries them; the MetricMap
        # facade keeps the historical dict shape at every call site
        self.metrics = MetricsRegistry("faults")
        self.consults = MetricMap(self.metrics, FAULT_SITES,
                                  prefix="consults_")
        self.fired = MetricMap(self.metrics, FAULT_SITES, prefix="fired_")

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def fire(self, site: str) -> bool:
        """One consult of ``site``: True iff a fault fires here."""
        rate = self.rates[site]
        self.consults[site] += 1
        if rate <= 0.0:
            return False
        hit = float(self._rng[site].random()) < rate
        if hit and (self.max_fires is None
                    or self.total_fired < self.max_fires):
            self.fired[site] += 1
            return True
        return False

    def pick(self, site: str, candidates):
        """Consult ``site``; on fire, return a deterministically chosen
        element of ``candidates`` (None otherwise / when empty)."""
        if not candidates:
            return None
        if not self.fire(site):
            return None
        j = int(self._victim_rng[site].integers(len(candidates)))
        return candidates[j]

    def stats(self) -> dict:
        return {
            "seed": self.seed,
            "consults": dict(self.consults),
            "fired": dict(self.fired),
            "total_fired": self.total_fired,
        }
