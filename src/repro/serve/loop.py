"""Batched serving loop: prefill a batch of prompts, then decode greedily
(or with temperature), streaming tokens out per step."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models.common import ShardRules
from repro.serve.step import jit_decode_step, jit_prefill


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0       # 0 => greedy
    seed: int = 0


def generate(
    cfg: ArchConfig,
    mesh,
    rules: ShardRules,
    params,
    prompts: np.ndarray,           # (B, S) int32
    extra=None,                    # vlm patches / audio frames
    serve: ServeConfig = ServeConfig(),
) -> np.ndarray:
    """Returns (B, max_new_tokens) int32 generated tokens."""
    B, S = prompts.shape
    n_ctx = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    max_len = n_ctx + serve.max_new_tokens
    shape = ShapeConfig("serve", "prefill", S, B)
    prefill_fn, _ = jit_prefill(cfg, mesh, rules, shape, max_len=max_len)
    cache, logits = prefill_fn(params, jnp.asarray(prompts), extra)

    dshape = ShapeConfig("serve", "decode", max_len, B)
    decode_fn, _ = jit_decode_step(cfg, mesh, rules, dshape)

    key = jax.random.PRNGKey(serve.seed)
    out = []
    cur = n_ctx
    for t in range(serve.max_new_tokens):
        if serve.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / serve.temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        out.append(np.asarray(tok))
        logits, cache = decode_fn(params, cache, tok, jnp.int32(cur))
        cur += 1
    return np.stack(out, axis=1)
