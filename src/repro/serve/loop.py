"""Batched serving entry points.

``generate`` is a thin compatibility wrapper over the continuous-batching
:class:`~repro.serve.engine.ServeEngine`: all prompts are submitted at
once into a ``max_slots = batch`` engine, so its behavior (greedy tokens
included — asserted in tests/test_serve_engine.py) matches the legacy
static loop while routing through the slotted cache, fused sampling, and
the AOT dispatch cache.

``generate_static`` is the legacy fixed-batch loop — one prefill, then
every sequence decodes to the full token budget with logits round-tripping
to host sampling each step.  It remains as the fallback for families the
slot engine doesn't cover (modality frontends with extra inputs) and as
the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models.common import ShardRules
from repro.serve.step import jit_decode_step, jit_prefill


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0       # 0 => greedy
    seed: int = 0


def generate(
    cfg: ArchConfig,
    mesh,
    rules: ShardRules,
    params,
    prompts: np.ndarray,           # (B, S) int32
    extra=None,                    # vlm patches / audio frames
    serve: ServeConfig | None = None,
) -> np.ndarray:
    """Returns (B, max_new_tokens) int32 generated tokens."""
    serve = serve or ServeConfig()
    if extra is not None or not registry.supports_slot_serving(cfg):
        return generate_static(cfg, mesh, rules, params, prompts, extra, serve)

    from repro.serve.engine import EngineConfig, ServeEngine

    B, S = prompts.shape
    engine = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(
            max_slots=B,
            max_len=S + serve.max_new_tokens,
            seed=serve.seed,
            # the wrapper serves equal-length prompts: one exact bucket
            prefill_buckets=(S,),
        ),
    )
    out = engine.run(
        list(np.asarray(prompts, np.int32)),
        max_new_tokens=serve.max_new_tokens,
        temperature=serve.temperature,
    )
    return np.stack(out, axis=0)


def generate_static(
    cfg: ArchConfig,
    mesh,
    rules: ShardRules,
    params,
    prompts: np.ndarray,
    extra=None,
    serve: ServeConfig | None = None,
) -> np.ndarray:
    """Legacy static-batch loop: prefill once, decode the whole batch to the
    full budget with host-side sampling (the pre-engine behavior)."""
    serve = serve or ServeConfig()
    B, S = prompts.shape
    n_ctx = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    max_len = n_ctx + serve.max_new_tokens
    shape = ShapeConfig("serve", "prefill", S, B)
    prefill_fn, _ = jit_prefill(cfg, mesh, rules, shape, max_len=max_len)
    cache, logits = prefill_fn(params, jnp.asarray(prompts), extra)

    dshape = ShapeConfig("serve", "decode", max_len, B)
    decode_fn, _ = jit_decode_step(cfg, mesh, rules, dshape)

    key = jax.random.PRNGKey(serve.seed)
    out = []
    cur = n_ctx
    for t in range(serve.max_new_tokens):
        if serve.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / serve.temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        out.append(np.asarray(tok))
        logits, cache = decode_fn(params, cache, tok, jnp.int32(cur))
        cur += 1
    return np.stack(out, axis=1)
