"""Paged KV cache: host-side block allocator + device-resident paged state.

The slotted cache reserves ``max_slots x max_len`` KV positions up front —
every lane pays worst-case HBM whether its request is 4 tokens or 400.
The paged layout replaces the per-lane tensor with a **shared pool** of
fixed-size blocks:

    cache {k,v}  (L[,2], num_blocks, block_size, Hk, dh)
    tables       (max_slots, max_len // block_size) int32

A lane owns a *block table* row: entry ``j`` is the physical block holding
logical positions ``[j*bs, (j+1)*bs)``.  Blocks are allocated on demand —
at admission for the prompt, then one at a time as decode crosses block
boundaries — and returned to the free list on eviction.  HBM reservation
is ``num_blocks * block_size`` positions total, sized to *expected* load
rather than ``max_slots * max_len`` worst case.

Physical block **0 is the null block**: a write sink that is never
allocated and never read.  Unmapped table entries point at it, so garbage
writes from padded prefill tails, freed lanes, and mid-prefill decode
steps land there instead of corrupting live blocks (the paged analogue of
the slotted cache's lazy-overwrite argument).

The allocator and tables are **host-side** (plain Python/numpy): the
engine mirrors scheduling state anyway, so block accounting adds zero
device syncs.  The device sees only the ``tables`` array, re-pushed as a
state leaf whenever a row changes (a few hundred bytes, amortised over
many steps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import registry

NULL_BLOCK = 0


def blocks_for(positions: int, block_size: int) -> int:
    """Blocks needed to hold ``positions`` KV positions."""
    if positions <= 0:
        return 0
    return -(-positions // block_size)


class BlockAllocator:
    """Fixed pool of KV blocks with a free list.

    Block 0 is reserved as the null/write-sink block and is never handed
    out.  ``alloc`` pops the lowest free id (deterministic across runs so
    block layouts — and therefore the bytes the bench reports — are
    reproducible); ``free`` returns a block.  ``peak_in_use`` tracks the
    high-water mark for the bench's ``kv_used_bytes``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the null block), "
                f"got {num_blocks}"
            )
        if block_size < 1:
            raise ValueError(f"block_size must be positive, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # sorted free list, popped from the front: lowest ids first
        self._free = list(range(1, num_blocks))
        self._allocated: set[int] = set()
        self.peak_in_use = 0

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the null block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._allocated)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        b = self._free.pop(0)
        self._allocated.add(b)
        self.peak_in_use = max(self.peak_in_use, len(self._allocated))
        return b

    def free(self, block: int) -> None:
        if block == NULL_BLOCK:
            raise ValueError("cannot free the null block")
        if block not in self._allocated:
            raise ValueError(f"block {block} is not allocated")
        self._allocated.remove(block)
        # keep the free list sorted so allocation order is deterministic
        import bisect
        bisect.insort(self._free, block)

    def check(self) -> None:
        """Invariant sweep (used by the property tests)."""
        assert len(self._free) + len(self._allocated) == self.capacity
        assert not (set(self._free) & self._allocated)
        assert NULL_BLOCK not in self._allocated and NULL_BLOCK not in self._free
        assert self._free == sorted(self._free)


class SlotTables:
    """Per-slot block tables mirrored on host.

    Invariant (the *compaction* invariant): every row is a contiguous
    prefix of live block ids followed by ``NULL_BLOCK`` padding — blocks
    are appended in logical order and only released all at once, so a
    lane's mapped region is always ``[0, mapped(slot) * block_size)``.
    """

    def __init__(self, max_slots: int, blocks_per_slot: int):
        self.table = np.zeros((max_slots, blocks_per_slot), np.int32)
        self._blocks: list[list[int]] = [[] for _ in range(max_slots)]

    @property
    def blocks_per_slot(self) -> int:
        return self.table.shape[1]

    def mapped(self, slot: int) -> int:
        """Number of blocks mapped for ``slot``."""
        return len(self._blocks[slot])

    def blocks(self, slot: int) -> tuple[int, ...]:
        return tuple(self._blocks[slot])

    def append(self, slot: int, block: int) -> None:
        """Map ``block`` as the next logical block of ``slot``."""
        if block == NULL_BLOCK:
            raise ValueError("cannot map the null block")
        row = self._blocks[slot]
        if len(row) >= self.blocks_per_slot:
            raise ValueError(f"slot {slot} table is full")
        self.table[slot, len(row)] = block
        row.append(block)

    def release(self, slot: int) -> list[int]:
        """Unmap every block of ``slot``; returns them (caller frees)."""
        out, self._blocks[slot] = self._blocks[slot], []
        self.table[slot, :] = NULL_BLOCK
        return out

    def check(self) -> None:
        """Compaction + uniqueness invariants (property tests)."""
        seen: set[int] = set()
        for slot, row in enumerate(self._blocks):
            n = len(row)
            assert list(self.table[slot, :n]) == row
            assert not self.table[slot, n:].any(), "non-contiguous table row"
            assert NULL_BLOCK not in row
            dup = seen & set(row)
            assert not dup, f"blocks {dup} mapped in two slots"
            seen |= set(row)


# ---------------------------------------------------------------------------
# Device-resident paged state
# ---------------------------------------------------------------------------


def paged_state_specs(cfg: ArchConfig, mesh, max_slots: int, max_len: int,
                      num_blocks: int, block_size: int):
    """Abstract paged state: ``({leaf: sds}, {leaf: NamedSharding})``.

    Mirrors ``cache.slot_state_specs`` but the KV tensors are a shared
    block pool and the per-slot vectors gain the ``tables`` rows.
    """
    from .cache import sched_specs  # local import: cache imports registry too

    if max_len % block_size:
        raise ValueError(
            f"max_len ({max_len}) must be a multiple of block_size "
            f"({block_size})"
        )
    mod = registry.get_module(cfg)
    cache_sds = mod.make_paged_cache_specs(cfg, num_blocks, block_size)
    cache_ps = mod.paged_cache_pspec(cfg, mesh, num_blocks)
    rep = NamedSharding(mesh, P())
    sched_sds, sched_sh = sched_specs(mesh, max_slots)
    nb = max_len // block_size
    sds = {
        "cache": cache_sds,
        "tables": jax.ShapeDtypeStruct((max_slots, nb), jnp.int32),
        **sched_sds,
    }
    sh = {
        "cache": jax.tree.map(
            lambda p: NamedSharding(mesh, p), cache_ps,
            is_leaf=lambda x: isinstance(x, P),
        ),
        "tables": rep,
        **sched_sh,
    }
    return sds, sh


def make_paged_state(cfg: ArchConfig, mesh, max_slots: int, max_len: int,
                     num_blocks: int, block_size: int, seed: int = 0) -> dict:
    """Allocate the device-resident paged state (all tables null)."""
    sds, sh = paged_state_specs(
        cfg, mesh, max_slots, max_len, num_blocks, block_size)
    state = jax.tree.map(
        lambda s, d: jax.device_put(jnp.zeros(s.shape, s.dtype), d), sds, sh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    state["key"] = jax.device_put(
        jax.random.PRNGKey(seed).astype(jnp.uint32), sh["key"]
    )
    return state


def cache_nbytes(cache_tree) -> int:
    """Total bytes of the KV cache leaves (arrays or ShapeDtypeStructs)."""
    return sum(
        int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(cache_tree)
    )
