"""Paged KV cache: host-side block allocator + device-resident paged state.

The slotted cache reserves ``max_slots x max_len`` KV positions up front —
every lane pays worst-case HBM whether its request is 4 tokens or 400.
The paged layout replaces the per-lane tensor with a **shared pool** of
fixed-size blocks:

    cache {k,v}  (L[,2], num_blocks, block_size, Hk, dh)
    tables       (max_slots, max_len // block_size) int32

A lane owns a *block table* row: entry ``j`` is the physical block holding
logical positions ``[j*bs, (j+1)*bs)``.  Blocks are allocated on demand —
at admission for the prompt, then one at a time as decode crosses block
boundaries — and returned to the free list on eviction.  HBM reservation
is ``num_blocks * block_size`` positions total, sized to *expected* load
rather than ``max_slots * max_len`` worst case.

Physical block **0 is the null block**: a write sink that is never
allocated and never read.  Unmapped table entries point at it, so garbage
writes from padded prefill tails, freed lanes, and mid-prefill decode
steps land there instead of corrupting live blocks (the paged analogue of
the slotted cache's lazy-overwrite argument).

The allocator and tables are **host-side** (plain Python/numpy): the
engine mirrors scheduling state anyway, so block accounting adds zero
device syncs.  The device sees only the ``tables`` array, re-pushed as a
state leaf whenever a row changes (a few hundred bytes, amortised over
many steps).

**Prefix caching** rides on two extensions of the allocator:

* every live block carries a **refcount** — a block a prompt prefix
  shares is mapped by several lanes at once and only returns to the free
  list when the last lane releases it;
* a **prefix-hash index** keyed by a block-aligned rolling hash of the
  token sequence (``prefix_keys``): when a full block's KV has been
  written, the owning lane *publishes* it, and a later request whose
  prompt starts with the same tokens *shares* the cached chain instead of
  recomputing it.  A published block whose refcount drops to 0 parks in a
  **cached** LRU set — still indexed, revivable by a future hit, and
  reclaimed (evicted from the index) only when the free list runs dry.

So each allocatable block is in exactly one of three states — *free*,
*live* (ref >= 1), or *cached* (ref == 0, indexed) — and
``free + live + cached == capacity`` is the conservation invariant the
property tests and the fuzz harness sweep after every step.
"""
from __future__ import annotations

import bisect
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import registry

NULL_BLOCK = 0


def blocks_for(positions: int, block_size: int) -> int:
    """Blocks needed to hold ``positions`` KV positions."""
    if positions <= 0:
        return 0
    return -(-positions // block_size)


def prefix_keys(tokens, block_size: int) -> list[bytes]:
    """Chain keys for every full block-aligned prefix of ``tokens``.

    Key ``j`` digests tokens ``[0, (j+1)*block_size)`` through a rolling
    sha256 — a collision-free stand-in for a rolling hash, so two chains
    share a key iff their token prefixes are identical (a polynomial hash
    collision here would silently splice one prompt's KV into another).
    The chain structure means key ``j`` commits to the *whole* history,
    not just block ``j``'s tokens: block contents depend on every earlier
    position through attention.
    """
    t = np.ascontiguousarray(np.asarray(tokens, np.int32))
    h = hashlib.sha256()
    out: list[bytes] = []
    for j in range(t.size // block_size):
        h.update(t[j * block_size:(j + 1) * block_size].tobytes())
        out.append(h.digest())
    return out


class BlockAllocator:
    """Fixed pool of KV blocks: free list + per-block refcounts + a
    prefix-hash index of published (fully written, content-addressed)
    blocks.

    Block 0 is reserved as the null/write-sink block and is never handed
    out.  ``alloc`` pops the lowest free id (deterministic across runs so
    block layouts — and therefore the bytes the bench reports — are
    reproducible), falling back to evicting the LRU *cached* block when
    the free list is empty; ``free`` drops one reference, parking
    published blocks in the cached set and returning unpublished ones to
    the free list at refcount 0; ``share`` takes a reference on a live or
    cached block (a prefix-cache hit).  ``peak_in_use`` tracks the
    live-block high-water mark for the bench's ``kv_used_bytes``.

    Invariants (swept by :meth:`check` after every fuzzer step): each of
    the ``capacity`` allocatable blocks is in exactly one of the three
    states, so ``free + live + cached == capacity``; live refcounts are
    ``>= 1``; every cached block is indexed and every index entry points
    at a live-or-cached block (a lookup can never return a freed block);
    the free list stays sorted (allocation order is deterministic).
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the null block), "
                f"got {num_blocks}"
            )
        if block_size < 1:
            raise ValueError(f"block_size must be positive, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # sorted free list, popped from the front: lowest ids first
        self._free = list(range(1, num_blocks))
        self._ref: dict[int, int] = {}          # live blocks -> refcount >= 1
        self._cached: OrderedDict[int, None] = OrderedDict()  # LRU, ref == 0
        self._index: dict[bytes, int] = {}      # chain key -> block
        self._block_key: dict[int, bytes] = {}  # published block -> its key
        self.peak_in_use = 0
        self.hits = 0          # lookup chains that matched at least a block
        self.misses = 0
        self.cache_evictions = 0
        # optional hook fired as ``on_evict(block, key)`` when an LRU
        # *cached* block is reclaimed for reuse — before the index entry
        # is dropped and before the new owner writes, so the host tier
        # can still read the block's KV off-device (second-level prefix
        # cache: reclaimed chains spill instead of dying)
        self.on_evict = None

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the null block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_cached(self) -> int:
        """Published blocks with refcount 0 (revivable, reclaimable)."""
        return len(self._cached)

    @property
    def available(self) -> int:
        """Blocks an ``alloc`` can hand out: free + reclaimable cached."""
        return len(self._free) + len(self._cached)

    @property
    def in_use(self) -> int:
        """Live blocks (refcount >= 1)."""
        return len(self._ref)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def _forget(self, block: int) -> None:
        """Drop a block's index entry (cache eviction / reclamation)."""
        key = self._block_key.pop(block, None)
        if key is not None and self._index.get(key) == block:
            del self._index[key]

    def alloc(self) -> int:
        if self._free:
            b = self._free.pop(0)
        elif self._cached:
            b, _ = self._cached.popitem(last=False)   # evict LRU cached
            if self.on_evict is not None:
                self.on_evict(b, self._block_key.get(b))
            self._forget(b)
            self.cache_evictions += 1
        else:
            raise RuntimeError("KV block pool exhausted")
        self._ref[b] = 1
        self.peak_in_use = max(self.peak_in_use, len(self._ref))
        return b

    def share(self, block: int) -> int:
        """Take one more reference on a live or cached block (prefix hit).
        Returns the block for chaining."""
        if block in self._ref:
            self._ref[block] += 1
        elif block in self._cached:
            del self._cached[block]                   # revive
            self._ref[block] = 1
            self.peak_in_use = max(self.peak_in_use, len(self._ref))
        else:
            raise ValueError(f"block {block} is not allocated or cached")
        return block

    def free(self, block: int) -> None:
        if block == NULL_BLOCK:
            raise ValueError("cannot free the null block")
        if block not in self._ref:
            raise ValueError(f"block {block} is not allocated")
        self._ref[block] -= 1
        if self._ref[block]:
            return
        del self._ref[block]
        if block in self._block_key:
            self._cached[block] = None                # park, MRU end
        else:
            # keep the free list sorted so allocation order is deterministic
            bisect.insort(self._free, block)

    # -- prefix index ---------------------------------------------------
    def publish(self, block: int, key: bytes) -> bool:
        """Index a fully written live block under its chain ``key``.
        Idempotent: if the key is already indexed (another lane produced
        the same chain first), the existing entry wins and this block
        stays unpublished.  Returns True if the block was indexed."""
        if block not in self._ref:
            raise ValueError(f"cannot publish non-live block {block}")
        if key in self._index or block in self._block_key:
            return False
        self._index[key] = block
        self._block_key[block] = key
        return True

    def indexed(self, key: bytes) -> bool:
        """Whether a chain key is device-indexed (no hit/miss counting —
        the host tier probes this to decide what to promote)."""
        return key in self._index

    def lookup(self, keys: list[bytes]) -> list[int]:
        """Longest indexed chain prefix of ``keys`` (no refs taken —
        callers ``share`` the blocks they actually map)."""
        out: list[int] = []
        for k in keys:
            b = self._index.get(k)
            if b is None:
                break
            out.append(b)
        if out:
            self.hits += 1
        elif keys:
            self.misses += 1
        return out

    def check(self) -> None:
        """Invariant sweep (property tests + the cross-engine fuzzer):
        free/live/cached partition the pool, refcounts are positive,
        every cached block is indexed, and every index entry points at a
        live-or-cached block."""
        free, live, cached = set(self._free), set(self._ref), set(self._cached)
        assert len(free) + len(live) + len(cached) == self.capacity, \
            "free + live + cached != pool"
        assert not (free & live) and not (free & cached) and not (live & cached)
        assert NULL_BLOCK not in free | live | cached
        assert self._free == sorted(self._free)
        assert all(r >= 1 for r in self._ref.values())
        for b in cached:
            assert b in self._block_key, f"cached block {b} has no key"
        for b, key in self._block_key.items():
            assert self._index.get(key) == b
            assert b in live or b in cached, f"indexed block {b} was freed"
        assert len(self._block_key) == len(self._index)


class SlotTables:
    """Per-slot block tables mirrored on host.

    Invariant (the *compaction* invariant): every row is a contiguous
    prefix of live block ids followed by ``NULL_BLOCK`` padding — blocks
    are appended in logical order and only released all at once, so a
    lane's mapped region is always ``[0, mapped(slot) * block_size)``.
    """

    def __init__(self, max_slots: int, blocks_per_slot: int):
        self.table = np.zeros((max_slots, blocks_per_slot), np.int32)
        self._blocks: list[list[int]] = [[] for _ in range(max_slots)]

    @property
    def blocks_per_slot(self) -> int:
        return self.table.shape[1]

    def mapped(self, slot: int) -> int:
        """Number of blocks mapped for ``slot``."""
        return len(self._blocks[slot])

    def blocks(self, slot: int) -> tuple[int, ...]:
        return tuple(self._blocks[slot])

    def append(self, slot: int, block: int) -> None:
        """Map ``block`` as the next logical block of ``slot``."""
        if block == NULL_BLOCK:
            raise ValueError("cannot map the null block")
        row = self._blocks[slot]
        if len(row) >= self.blocks_per_slot:
            raise ValueError(f"slot {slot} table is full")
        self.table[slot, len(row)] = block
        row.append(block)

    def release(self, slot: int) -> list[int]:
        """Unmap every block of ``slot``; returns them (caller frees)."""
        out, self._blocks[slot] = self._blocks[slot], []
        self.table[slot, :] = NULL_BLOCK
        return out

    def check(self, *, refcount=None) -> None:
        """Compaction + uniqueness invariants (property tests).

        Default: no block may be mapped by two slots.  With ``refcount``
        (a callable, e.g. ``BlockAllocator.refcount``), prefix-cache
        sharing is legal and the check instead demands every block's
        refcount covers its mapping multiplicity (and is live at all).
        """
        counts: dict[int, int] = {}
        for slot, row in enumerate(self._blocks):
            n = len(row)
            assert list(self.table[slot, :n]) == row
            assert not self.table[slot, n:].any(), "non-contiguous table row"
            assert NULL_BLOCK not in row
            if refcount is None:
                dup = set(counts) & set(row)
                assert not dup, f"blocks {dup} mapped in two slots"
            for b in row:
                counts[b] = counts.get(b, 0) + 1
        if refcount is not None:
            for b, n in counts.items():
                assert refcount(b) >= n, (
                    f"block {b} mapped {n}x but refcount {refcount(b)}")


# ---------------------------------------------------------------------------
# Host-RAM tier
# ---------------------------------------------------------------------------


class LaneSpill:
    """One preempted/parked lane's decode state, resident in host RAM.

    ``kind`` selects the payload shape:

    * ``"paged"`` — ``blocks`` is one ``{leaf: ndarray}`` per mapped
      block, in logical order (the lane's KV, block by block);
    * ``"lane"``  — ``leaves`` is one ``{leaf: ndarray}`` lane snapshot
      (slotted KV segment and/or recurrent leaves, per
      ``registry.lane_leaf_axes``).

    ``prefilled``/``generated`` pin the schedule position the payload
    corresponds to: restoring writes the payload back and resumes decode
    at ``prompt[plen + generated - 1]`` — the exact input the lane would
    have fed next — so continuation is bitwise identical to never having
    been evicted.
    """

    __slots__ = ("rid", "kind", "prefilled", "generated", "blocks",
                 "leaves", "nbytes")

    def __init__(self, rid: int, kind: str, prefilled: int, generated: int,
                 blocks: list | None = None, leaves: dict | None = None):
        self.rid = rid
        self.kind = kind
        self.prefilled = prefilled
        self.generated = generated
        self.blocks = blocks or []
        self.leaves = leaves
        self.nbytes = sum(
            a.nbytes for tree in (self.blocks + [self.leaves or {}])
            for a in tree.values())


class HostTier:
    """Second-level store for KV/decode state in host RAM.

    Two payload families share one bounded pool:

    * **lane spills** (:class:`LaneSpill`, keyed by request id) — a
      preempted or parked lane's whole decode state, restored O(copy) at
      resume instead of O(generated-tokens) decode replay;
    * **prefix blocks** (keyed by the same sha256 chain keys as
      :class:`BlockAllocator`'s index) — LRU-reclaimed prefix-cache
      blocks spill here instead of dying, making the tier a second-level
      prefix cache consulted by admission and the router's cache-aware
      scoring.

    Capacity is counted in **block-sized units** (``capacity_blocks``;
    ``None`` = unbounded): each paged payload block is one unit, and
    prefix blocks are the only evictable residents (lane spills pin their
    units until restored or dropped — they back an in-flight request).
    Whole-lane snapshots (``kind == "lane"``) are O(1) per lane and
    outside the block budget; they are bounded by the fleet's lane count,
    not by traffic.

    One tier may be shared by every replica behind a router: request ids
    are fleet-unique and payloads are plain host arrays, so a crashed
    replica's spills survive it and failover restores them O(copy) on the
    survivor.

    Conservation: with a bounded tier attached to an allocator, the
    three-state device lifecycle grows a fourth, *spilled*, state and
    :func:`check_tiered` sweeps ``free + live + cached + spilled ==
    capacity`` across both pools.
    """

    def __init__(self, capacity_blocks: int | None = None):
        if capacity_blocks is not None and capacity_blocks < 0:
            raise ValueError(f"capacity_blocks must be >= 0, got "
                             f"{capacity_blocks}")
        self.capacity_blocks = capacity_blocks
        self._lanes: dict[int, LaneSpill] = {}
        self._prefix: OrderedDict[bytes, dict] = OrderedDict()  # LRU
        self._bytes = 0
        # monotone counters (the engine folds these into its MetricMap)
        self.lane_spills = 0
        self.lane_restores = 0
        self.prefix_spills = 0
        self.prefix_hits = 0
        self.drops = 0          # payloads rejected or LRU-dropped for room
        self.spilled_bytes = 0
        self.restored_bytes = 0

    # -- accounting -----------------------------------------------------
    @property
    def spilled_blocks(self) -> int:
        """Block-sized units resident (prefix blocks + paged lane blocks)."""
        return len(self._prefix) + sum(
            len(sp.blocks) for sp in self._lanes.values())

    @property
    def spilled_lanes(self) -> int:
        return len(self._lanes)

    @property
    def host_free(self) -> int | None:
        """Remaining block units (None when unbounded)."""
        if self.capacity_blocks is None:
            return None
        return self.capacity_blocks - self.spilled_blocks

    @property
    def used_bytes(self) -> int:
        return self._bytes

    @staticmethod
    def _tree_bytes(sp_or_payload) -> int:
        if isinstance(sp_or_payload, LaneSpill):
            return sp_or_payload.nbytes
        return sum(a.nbytes for a in sp_or_payload.values())

    def _make_room(self, units: int) -> bool:
        """Free ``units`` block units by LRU-dropping prefix blocks.
        Lane spills are never evicted (they back in-flight requests)."""
        if self.capacity_blocks is None:
            return True
        while self.capacity_blocks - self.spilled_blocks < units and self._prefix:
            _, payload = self._prefix.popitem(last=False)
            self._bytes -= self._tree_bytes(payload)
            self.drops += 1
        return self.capacity_blocks - self.spilled_blocks >= units

    # -- lane spills ----------------------------------------------------
    def put_lane(self, sp: LaneSpill) -> bool:
        """Admit a lane spill; False if the tier can't make room (the
        caller falls back to decode replay)."""
        old = self._lanes.pop(sp.rid, None)
        if old is not None:
            self._bytes -= old.nbytes
        if not self._make_room(len(sp.blocks)):
            self.drops += 1
            return False
        self._lanes[sp.rid] = sp
        self._bytes += sp.nbytes
        self.lane_spills += 1
        self.spilled_bytes += sp.nbytes
        return True

    def has_lane(self, rid: int) -> bool:
        return rid in self._lanes

    def peek_lane(self, rid: int) -> LaneSpill | None:
        return self._lanes.get(rid)

    def pop_lane(self, rid: int) -> LaneSpill | None:
        """Remove and return a lane spill (restore commit)."""
        sp = self._lanes.pop(rid, None)
        if sp is not None:
            self._bytes -= sp.nbytes
            self.lane_restores += 1
            self.restored_bytes += sp.nbytes
        return sp

    def drop_lane(self, rid: int) -> None:
        """Discard a lane spill without restoring (terminal request)."""
        sp = self._lanes.pop(rid, None)
        if sp is not None:
            self._bytes -= sp.nbytes

    # -- prefix blocks --------------------------------------------------
    def put_block(self, key: bytes, payload: dict) -> bool:
        """Admit one reclaimed prefix block under its chain ``key``."""
        if key in self._prefix:
            self._prefix.move_to_end(key)             # already resident
            return True
        if not self._make_room(1):
            self.drops += 1
            return False
        self._prefix[key] = payload
        self._bytes += self._tree_bytes(payload)
        self.prefix_spills += 1
        self.spilled_bytes += self._tree_bytes(payload)
        return True

    def has_block(self, key: bytes) -> bool:
        return key in self._prefix

    def match_chain(self, keys: list[bytes], start: int = 0) -> int:
        """How many consecutive chain keys from ``keys[start:]`` are
        host-resident — the tier's extension of a device chain match
        (admission restore depth, router cache-aware score)."""
        n = 0
        for k in keys[start:]:
            if k not in self._prefix:
                break
            n += 1
        return n

    def pop_block(self, key: bytes) -> dict | None:
        """Remove and return a prefix block (restored to the device and
        re-published there — move semantics keep one owner per chain)."""
        payload = self._prefix.pop(key, None)
        if payload is not None:
            self._bytes -= self._tree_bytes(payload)
            self.prefix_hits += 1
            self.restored_bytes += self._tree_bytes(payload)
        return payload

    def discard_block(self, key: bytes) -> None:
        """Drop a host copy without restoring — called when the same
        chain key gets (re)published on device, so each key has exactly
        one owner (device index XOR host tier)."""
        payload = self._prefix.pop(key, None)
        if payload is not None:
            self._bytes -= self._tree_bytes(payload)

    def check(self) -> None:
        """Invariant sweep: byte tally matches the payloads, and a
        bounded tier never exceeds its block budget."""
        nb = sum(sp.nbytes for sp in self._lanes.values()) + sum(
            self._tree_bytes(p) for p in self._prefix.values())
        assert nb == self._bytes, f"tier byte tally {self._bytes} != {nb}"
        for rid, sp in self._lanes.items():
            assert sp.rid == rid and sp.kind in ("paged", "lane")
            assert (sp.kind == "paged") == (sp.leaves is None)
        if self.capacity_blocks is not None:
            assert self.spilled_blocks <= self.capacity_blocks, (
                f"tier over budget: {self.spilled_blocks} block units > "
                f"{self.capacity_blocks}")


def check_tiered(alloc: BlockAllocator, tier: HostTier | None) -> None:
    """Four-state conservation across the HBM pool and the host tier.

    Each pool keeps its own partition (``free + live + cached ==
    capacity`` on device, ``spilled + host_free == capacity_blocks`` on
    a bounded tier), and the cross-pool ownership invariant says every
    chain key has exactly one owner: a key is indexed on device **xor**
    resident in the host tier (spill moves it out, promotion moves it
    back, a republish discards the host copy).  Together:
    ``free + live + cached + spilled == capacity`` over the combined
    pool with no block counted twice.
    """
    alloc.check()
    if tier is None:
        return
    tier.check()
    both = set(alloc._index) & set(tier._prefix)
    assert not both, (
        f"{len(both)} chain keys owned by device index AND host tier")
    if tier.capacity_blocks is None:
        return
    total = alloc.num_free + alloc.in_use + alloc.num_cached \
        + tier.spilled_blocks + tier.host_free
    assert total == alloc.capacity + tier.capacity_blocks, (
        f"tiered conservation broken: free {alloc.num_free} + live "
        f"{alloc.in_use} + cached {alloc.num_cached} + spilled "
        f"{tier.spilled_blocks} + host_free {tier.host_free} != "
        f"{alloc.capacity + tier.capacity_blocks}")


# ---------------------------------------------------------------------------
# Device-resident paged state
# ---------------------------------------------------------------------------


def paged_state_specs(cfg: ArchConfig, mesh, max_slots: int, max_len: int,
                      num_blocks: int, block_size: int):
    """Abstract paged state: ``({leaf: sds}, {leaf: NamedSharding})``.

    Mirrors ``cache.slot_state_specs`` but the KV tensors are a shared
    block pool and the per-slot vectors gain the ``tables`` rows.
    """
    from .cache import sched_specs  # local import: cache imports registry too

    if max_len % block_size:
        raise ValueError(
            f"max_len ({max_len}) must be a multiple of block_size "
            f"({block_size})"
        )
    mod = registry.get_module(cfg)
    cache_sds = mod.make_paged_cache_specs(cfg, num_blocks, block_size)
    cache_ps = mod.paged_cache_pspec(cfg, mesh, num_blocks)
    rep = NamedSharding(mesh, P())
    sched_sds, sched_sh = sched_specs(mesh, max_slots)
    nb = max_len // block_size
    sds = {
        "cache": cache_sds,
        "tables": jax.ShapeDtypeStruct((max_slots, nb), jnp.int32),
        **sched_sds,
    }
    sh = {
        "cache": jax.tree.map(
            lambda p: NamedSharding(mesh, p), cache_ps,
            is_leaf=lambda x: isinstance(x, P),
        ),
        "tables": rep,
        **sched_sh,
    }
    return sds, sh


def make_paged_state(cfg: ArchConfig, mesh, max_slots: int, max_len: int,
                     num_blocks: int, block_size: int, seed: int = 0) -> dict:
    """Allocate the device-resident paged state (all tables null)."""
    sds, sh = paged_state_specs(
        cfg, mesh, max_slots, max_len, num_blocks, block_size)
    state = jax.tree.map(
        lambda s, d: jax.device_put(jnp.zeros(s.shape, s.dtype), d), sds, sh,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    state["key"] = jax.device_put(
        jax.random.PRNGKey(seed).astype(jnp.uint32), sh["key"]
    )
    return state


def cache_nbytes(cache_tree) -> int:
    """Total bytes of the KV cache leaves (arrays or ShapeDtypeStructs)."""
    return sum(
        int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(cache_tree)
    )
