"""Multi-replica serving front-end: a health-checked router.

The :class:`Router` owns the fleet-level admission queue and drives N
in-process :class:`~repro.serve.engine.ServeEngine` replicas — the
Synkhronos worker abstraction taken fleet-scale: clients keep the serial
``submit / completions`` surface of a single engine while the router
handles placement, failure, and capacity across replicas.  Everything is
deterministic and host-side: an injectable clock, seeded replica faults
(:class:`~repro.serve.faults.FaultPlan`), and a tick loop (:meth:`step`)
whose behaviour is a pure function of (stream, seeds, config) — the same
discipline that makes the engine fuzzers replayable.

Four capabilities:

**Routing policy.**  Least-loaded by default (ties break to the lowest
replica index).  When the engines run a prefix cache, routing is
*cache-aware*: the rolling-hash chain of the prompt (``prefix_keys``,
PR 4's index) is probed against every accepting replica's published-block
index, and the replica with the longest matched chain wins — a shared
prefix only pays prefill once per replica instead of once per request.

**Crash failover.**  Replica faults are injected at two plan sites:
``replica_crash`` (the engine process dies — its host state is gone) and
``replica_stall`` (it hangs without dying; a step-budget health check
detects the missing progress).  Either way the router declares the
replica dead and NEVER touches its engine again: every in-flight request
is rebuilt from the router's own stream mirror — prompt, sampling state,
and the tokens observed so far — and requeued at the admission-queue
front as a resume.  Re-admission on a survivor re-prefills the prompt
and replays the mirrored tokens through decode, so the completed stream
is bitwise the fault-free one under greedy decoding (the PR-4/6 replay
property).  Failover is bounded per request (``max_failovers``);
exhaustion is a structured ``"failed"`` completion, not an exception.

**Graceful degradation.**  The admission queue is bounded: a submit
beyond ``shed_queue_depth`` terminates immediately with status
``"shed"`` (a first-class terminal status — shed costs nothing, while
an admitted request that times out at 90% completion wasted a lane).
When deadlines are in play the router also sheds *early*: a request
whose TTL cannot cover the estimated queue wait (EWMA of recent service
times over the fleet's live lane capacity) is hopeless at admission time
and dropped before it queues.  As replicas die the fleet degrades in
throughput, never in correctness.

**Zero-downtime drain.**  :meth:`drain` stops admission to one replica
and synchronously migrates everything it holds onto the survivors via
the engine's per-request export (preempt + serialize) / import (requeue
elsewhere) path — no request is lost, no stream perturbed.  This is the
enabling primitive for live weight swap: drain, republish weights,
:meth:`reinstate`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from ..core.aot import AotCache
from ..obs import MetricMap, Observer
from .engine import STATUSES, Completion, EngineConfig, ServeEngine
from .faults import FaultPlan
from .paged import HostTier, prefix_keys


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    replicas: int = 2
    # bounded admission queue: a submit arriving when the router queue
    # already holds this many requests terminates with status "shed"
    shed_queue_depth: int = 64
    # health check: a replica holding work that makes no progress for
    # this many consecutive router ticks is declared dead and failed over
    stall_budget: int = 3
    # per-request budget of crash/stall migrations before the request
    # terminates "failed" (drain migrations don't count — the source
    # engine is healthy and the export is lossless)
    max_failovers: int = 3
    # extra queued requests a replica may hold beyond its decode lanes
    # before the router stops feeding it (0 = dispatch only into lanes)
    dispatch_depth: int = 0

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError("need at least one replica")
        if self.shed_queue_depth < 1:
            raise ValueError("shed_queue_depth must be >= 1")
        if self.stall_budget < 1:
            raise ValueError("stall_budget must be >= 1")


@dataclasses.dataclass
class _Record:
    """The router's own durable truth for one in-flight request.

    Mirrors of the placed replica's emitted stream are synced after
    every replica step; crash failover reads ONLY these mirrors — a
    dead engine's host dicts are off-limits, exactly as they would be
    after a real process death."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float
    top_k: int
    top_p: float
    submit_time: float
    deadline: float | None
    limit: int
    replica: int | None = None     # current placement (None = router queue)
    dispatch_time: float = 0.0
    # fleet build count at dispatch: if it advanced by collection time,
    # the service interval absorbed a compile and must not feed the
    # shed-policy EWMA (see _collect)
    dispatch_builds: int = 0
    failovers: int = 0
    tokens: list = dataclasses.field(default_factory=list)
    token_times: list = dataclasses.field(default_factory=list)
    retries: int = 0


class ReplicaHandle:
    """One engine replica plus the router's health view of it."""

    def __init__(self, idx: int, engine: ServeEngine):
        self.idx = idx
        self.engine = engine
        self.state = "live"        # "live" | "drained" | "dead"
        self.stalled = False       # injected hang: step() stops advancing
        self.last_progress = 0     # router tick of the last observed progress

    def load(self) -> int:
        """Distinct in-flight requests this replica owns (lane occupants
        and queued resumes are both in ``live``; fresh queued requests
        are counted from the queue)."""
        e = self.engine
        return len(e.live) + sum(1 for r in e.queue if not r.resume)

    def accepting(self, capacity: int) -> bool:
        return self.state == "live" and self.load() < capacity


class Router:
    """Deterministic host-side front-end over N engine replicas.

    Construction mirrors :class:`ServeEngine` — same (cfg, mesh, rules,
    params) plus the per-replica :class:`EngineConfig` and the fleet
    :class:`RouterConfig`.  All replicas share one :class:`AotCache`
    (identical configs -> identical executable keys, so the fleet
    compiles once) and the first replica's device-resident params (a
    ``device_put`` of already-placed arrays is a no-op, so N replicas
    cost one HBM copy of the weights).

    ``faults`` is consulted at the two ``replica_*`` sites once per
    :meth:`step`; engine-level fault plans (the four per-engine sites)
    can be attached per replica via ``engine_faults``.
    """

    def __init__(
        self,
        cfg,
        mesh,
        rules,
        params,
        engine: EngineConfig = EngineConfig(),  # noqa: B008 - frozen, never mutated
        router: RouterConfig = RouterConfig(),  # noqa: B008 - frozen, never mutated
        *,
        aot: AotCache | None = None,
        clock: Callable[[], float] = time.perf_counter,
        faults: FaultPlan | None = None,
        engine_faults: list[FaultPlan | None] | None = None,
        obs: Observer | None = None,
        draft_params=None,
    ):
        if engine_faults is not None and len(engine_faults) != router.replicas:
            raise ValueError("engine_faults must have one entry per replica")
        self.econ = engine
        self.rc = router
        self.clock = clock
        self.faults = faults
        # the router keeps its own metrics registry; each replica engine
        # gets a child Observer (fresh registry so per-replica counters
        # never collide) sharing the router's tracer/recorder so every
        # event lands on one fleet timeline
        self.obs = obs if obs is not None else Observer(name="router")
        self._track = self.obs.name
        # NOT ``aot or ...``: AotCache defines __len__ (see ServeEngine)
        self.aot = aot if aot is not None else AotCache("router", obs=self.obs)
        # one host tier for the FLEET: request ids are router-unique and
        # payloads are host arrays, so a crashed replica's lane spills
        # survive it — failover on a survivor restores O(copy) instead
        # of replaying the stream — and any replica can serve another's
        # spilled prefix chains
        self.tier = HostTier(engine.host_tier_blocks) \
            if engine.host_tier else None
        self.replicas: list[ReplicaHandle] = []
        dev_params = params
        dev_draft = draft_params
        for i in range(router.replicas):
            eng = ServeEngine(
                cfg, mesh, rules, dev_params, engine, aot=self.aot,
                clock=clock,
                faults=engine_faults[i] if engine_faults else None,
                obs=self.obs.child(f"replica{i}"),
                host_tier=self.tier,
                draft_params=dev_draft)
            dev_params = eng.params     # share the placed copy fleet-wide
            dev_draft = eng.draft_params    # ditto for the draft weights
            self.replicas.append(ReplicaHandle(i, eng))
        self.queue: deque[_Record] = deque()
        self.records: dict[int, _Record] = {}
        self.completions: dict[int, Completion] = {}
        self.placements: dict[int, int] = {}    # rid -> last replica index
        self.counters = MetricMap(self.obs.metrics, (
            "submitted", "dispatched", "cache_routed",
            "migrated", "failovers", "replicas_dead",
            "stalls_injected", "stalls_detected",
            *(f"status_{st}" for st in STATUSES),
        ))
        self.tick = 0
        self._next_rid = 0
        # EWMA of dispatch->finish seconds for "ok" completions; feeds the
        # deadline-aware early shed (None until the first completion)
        self._ewma_service: float | None = None

    # ------------------------------------------------------------------
    # Client surface
    # ------------------------------------------------------------------
    def prebuild(self) -> None:
        """Compile the fleet's executables (one build per key — the
        cache is shared, so this costs the same as a single engine)."""
        for h in self.replicas:
            h.engine.prebuild()

    def submit(self, prompt, *, max_new_tokens: int = 16,
               temperature: float = 0.0, top_k: int | None = None,
               top_p: float | None = None, rid: int | None = None,
               deadline_s: float | None = None) -> int:
        """Queue a request fleet-wide; returns its request id.  Same
        surface as ``ServeEngine.submit`` — the caller cannot tell it is
        talking to a fleet until it reads ``stats``.  May terminate the
        request immediately with status ``"shed"`` (see the module
        docstring); the rid is always valid in ``completions`` or in
        flight."""
        prompt = self.replicas[0].engine.validate(prompt, max_new_tokens)
        eff_k = int(self.econ.top_k if top_k is None else top_k)
        eff_p = float(self.econ.top_p if top_p is None else top_p)
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be positive")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        now = self.clock()
        rec = _Record(
            rid, prompt, int(max_new_tokens), float(temperature), eff_k,
            eff_p, now,
            None if deadline_s is None else now + float(deadline_s),
            limit=int(prompt.size) + int(max_new_tokens) - 1)
        self.counters["submitted"] += 1
        if self.obs.tracer is not None:
            self.obs.mark("submit", rid, track=self._track,
                          plen=int(prompt.size), max_new=int(max_new_tokens))
        shed_reason = self._shed_reason(rec)
        if shed_reason is not None:
            self._finish_local(rec, "shed", error=shed_reason)
            return rid
        self.records[rid] = rec
        self.queue.append(rec)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it lives: router-queued, placed on
        a replica, or stranded on a dead one (pending failover).  Same
        contract as the engine's ``cancel``."""
        if rid in self.completions:
            return False
        rec = self.records.get(rid)
        if rec is None:
            raise KeyError(f"unknown rid {rid}")
        if rec.replica is None:
            self.queue.remove(rec)
            self._finish_local(rec, "cancelled")
            return True
        h = self.replicas[rec.replica]
        if h.state == "dead":
            # placement died with its replica; the mirror has the tokens
            self._finish_local(rec, "cancelled")
            return True
        h.engine.cancel(rid)
        self._sync(h)
        self._collect(h)
        return True

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.records)

    def step(self) -> bool:
        """One router tick: inject replica faults, expire router-queue
        deadlines, dispatch, step the replicas, sync stream mirrors,
        collect completions, health-check.  Returns True iff anything
        progressed (fault detection counts: it unblocks work)."""
        self.tick += 1
        progressed = self._inject_replica_faults()
        progressed |= self._expire_queue_deadlines()
        progressed |= self._dispatch()
        for h in self.replicas:
            if h.state == "dead" or not h.engine.has_work():
                if h.state != "dead":
                    h.last_progress = self.tick     # idle is healthy
                continue
            if h.stalled:
                continue        # injected hang: the engine never steps
            if h.engine.step():
                h.last_progress = self.tick
                progressed = True
            self._sync(h)
            self._collect(h)
        progressed |= self._health_check()
        return progressed

    def run(self, max_ticks: int = 200_000) -> None:
        """Drive :meth:`step` until the fleet is idle."""
        ticks = 0
        while self.has_work():
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                raise RuntimeError(
                    f"router failed to drain within {max_ticks} ticks "
                    f"(queue={len(self.queue)} inflight={len(self.records)})")

    # ------------------------------------------------------------------
    # Fleet lifecycle: kill / drain / reinstate
    # ------------------------------------------------------------------
    def kill(self, idx: int) -> None:
        """Declare replica ``idx`` dead (crash injection, health-check
        verdict, or an external supervisor).  Its engine is never
        touched again — failover rebuilds every in-flight request from
        the router's own stream mirrors, exactly what survives a real
        process death."""
        h = self.replicas[idx]
        if h.state == "dead":
            return
        h.state = "dead"
        self.counters["replicas_dead"] += 1
        self.obs.instant("replica_dead", track=self._track, replica=idx)
        self.obs.record("replica_dead", replica=idx, tick=self.tick)
        self._failover(idx)

    def drain(self, idx: int) -> int:
        """Zero-downtime drain: stop admission to replica ``idx`` and
        migrate everything it holds back through the admission queue
        (front, rid order — FCFS priority survives the move).  Unlike
        :meth:`kill` the engine is healthy here, so migration rides its
        lossless per-request export (a preempt that resumes elsewhere).
        Returns the number of requests migrated."""
        h = self.replicas[idx]
        if h.state != "live":
            raise ValueError(f"replica {idx} is {h.state!r}, not live")
        h.state = "drained"
        owned = sorted(
            (rec for rec in self.records.values() if rec.replica == idx),
            key=lambda r: r.rid, reverse=True)
        for rec in owned:
            snap = h.engine.export_request(rec.rid)
            comp = snap["completion"]
            if comp is not None:
                # the engine's recorded stream is the authority here
                rec.tokens = [int(t) for t in comp["tokens"]]
                rec.token_times = [float(t) for t in comp["token_times"]]
                rec.retries = int(comp["retries"])
            rec.replica = None
            self.queue.appendleft(rec)
            self.counters["migrated"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("drain", rec.rid, track=self._track,
                              replica=idx)
        assert not h.engine.has_work(), "drained replica still holds work"
        return len(owned)

    def reinstate(self, idx: int) -> None:
        """Return a drained replica to rotation (the tail of the live
        weight-swap cycle: drain -> republish -> reinstate)."""
        h = self.replicas[idx]
        if h.state != "drained":
            raise ValueError(f"replica {idx} is {h.state!r}, not drained")
        h.state = "live"
        h.last_progress = self.tick

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _shed_reason(self, rec: _Record) -> str | None:
        if all(h.state == "dead" for h in self.replicas):
            return "no live replicas"
        if len(self.queue) >= self.rc.shed_queue_depth:
            return (f"admission queue full "
                    f"(depth {len(self.queue)} >= {self.rc.shed_queue_depth})")
        # deadline-aware early shed: the queue drains in waves of the
        # fleet's lane capacity, each wave taking ~one EWMA service time;
        # a TTL that cannot cover even that optimistic estimate is
        # hopeless NOW, and shedding it is free
        if rec.deadline is None or self._ewma_service is None:
            return None
        lanes = sum(self.econ.max_slots
                    for h in self.replicas if h.state == "live")
        if lanes == 0:
            return None     # all drained: no basis for an estimate
        waves = len(self.queue) // lanes + 1
        est_finish = self.clock() + waves * self._ewma_service
        if est_finish > rec.deadline:
            return (f"deadline unreachable at queue depth {len(self.queue)} "
                    f"(est. {waves} waves x {self._ewma_service:.3f}s)")
        return None

    def _finish_local(self, rec: _Record, status: str,
                      error: str | None = None) -> None:
        """Terminate a request the router itself owns (shed / queued
        timeout / queued cancel / failover exhaustion), preserving the
        mirrored token prefix like an engine-side termination would.
        Engine-side terminations observe their own latency histograms and
        terminal marks (``ServeEngine._observe_terminal``); this is the
        matching exit point for router-owned ones, so every rid gets
        exactly one terminal event fleet-wide."""
        comp = Completion(
            rid=rec.rid, prompt_len=int(rec.prompt.size),
            max_new_tokens=rec.max_new_tokens, tokens=list(rec.tokens),
            token_times=list(rec.token_times), submit_time=rec.submit_time,
            finish_time=self.clock(), status=status, error=error,
            retries=rec.retries)
        self.completions[rec.rid] = comp
        self.counters[f"status_{status}"] += 1
        self.records.pop(rec.rid, None)
        if comp.tokens:
            self.obs.metrics.histogram(f"ttft_ms_{status}").observe(
                max(0.0, (comp.token_times[0] - comp.submit_time) * 1e3))
            self.obs.metrics.histogram(f"tpot_ms_{status}").observe(
                max(0.0, (comp.finish_time - comp.submit_time) * 1e3
                    / len(comp.tokens)))
        if self.obs.tracer is not None:
            self.obs.mark("terminal", rec.rid, track=self._track,
                          status=status, tokens=len(comp.tokens),
                          error=error)

    def _expire_queue_deadlines(self) -> bool:
        expired = [rec for rec in self.queue
                   if rec.deadline is not None
                   and self.clock() >= rec.deadline]
        for rec in expired:
            self.queue.remove(rec)
            self._finish_local(rec, "timeout")
        return bool(expired)

    def _inject_replica_faults(self) -> bool:
        if self.faults is None:
            return False
        hit = False
        victim = self.faults.pick(
            "replica_crash",
            [h.idx for h in self.replicas if h.state != "dead"])
        if victim is not None:
            self.kill(victim)
            hit = True
        victim = self.faults.pick(
            "replica_stall",
            [h.idx for h in self.replicas
             if h.state != "dead" and not h.stalled])
        if victim is not None:
            self.replicas[victim].stalled = True
            self.counters["stalls_injected"] += 1
            self.obs.instant("fault", track=self._track,
                             site="replica_stall", replica=victim)
            hit = True
        return hit

    def _dispatch(self) -> bool:
        capacity = self.econ.max_slots + self.rc.dispatch_depth
        progressed = False
        if self.queue and all(h.state == "dead" for h in self.replicas):
            # total fleet loss: nothing will ever serve the queue — fail
            # every queued request now (structured, like everything else)
            # rather than hold them hostage (a drained replica does NOT
            # trigger this: it can be reinstated)
            while self.queue:
                self._finish_local(self.queue.popleft(), "failed",
                                   error="no live replicas")
            return True
        while self.queue:
            accepting = [h for h in self.replicas if h.accepting(capacity)]
            if not accepting:
                break
            rec = self.queue.popleft()
            self._place(rec, self._route(rec, accepting))
            progressed = True
        return progressed

    def _route(self, rec: _Record, accepting: list[ReplicaHandle]
               ) -> ReplicaHandle:
        """Pick a replica for ``rec`` among ``accepting`` (non-empty)."""
        pool = accepting
        if self.econ.prefix_cache:
            keys = prefix_keys(rec.prompt, self.econ.page_size)
            scores = [len(h.engine.alloc.lookup(keys)) for h in accepting]
            if self.tier is not None:
                # the host tier extends every replica's device chain: a
                # replica whose device match continues in host RAM pays
                # only an O(copy) promotion for those blocks, not prefill
                scores = [sc + self.tier.match_chain(keys, start=sc)
                          for sc in scores]
            best = max(scores)
            if best > 0:
                self.counters["cache_routed"] += 1
                pool = [h for h, sc in zip(accepting, scores) if sc == best]
        return min(pool, key=lambda h: (h.load(), h.idx))

    def _place(self, rec: _Record, h: ReplicaHandle) -> None:
        rec.replica = h.idx
        rec.dispatch_time = self.clock()
        rec.dispatch_builds = self.aot.stats["builds"]
        self.placements[rec.rid] = h.idx
        resume = bool(rec.tokens) or rec.failovers > 0
        pending = {
            "rid": rec.rid, "prompt": [int(t) for t in rec.prompt],
            "max_new_tokens": rec.max_new_tokens,
            "temperature": rec.temperature, "top_k": rec.top_k,
            "top_p": rec.top_p, "submit_time": rec.submit_time,
            "deadline": rec.deadline, "resume": resume,
            "limit": rec.limit, "replay": [int(t) for t in rec.tokens],
        }
        completion = None
        if resume:
            completion = {
                "rid": rec.rid, "prompt_len": int(rec.prompt.size),
                "max_new_tokens": rec.max_new_tokens,
                "tokens": [int(t) for t in rec.tokens],
                "token_times": [float(t) for t in rec.token_times],
                "submit_time": rec.submit_time, "finish_time": 0.0,
                "status": "ok", "error": None, "retries": rec.retries,
            }
        if self.obs.tracer is not None:
            self.obs.mark("route", rec.rid, track=self._track,
                          replica=h.idx, resume=resume)
        h.engine.import_request(
            {"pending": pending, "completion": completion},
            front=resume)
        self.counters["dispatched"] += 1

    def _sync(self, h: ReplicaHandle) -> None:
        """Mirror the replica's live streams into the router's records —
        the failover truth, refreshed at every step boundary."""
        for rid, comp in h.engine.live.items():
            rec = self.records.get(rid)
            if rec is not None and rec.replica == h.idx:
                rec.tokens = list(comp.tokens)
                rec.token_times = list(comp.token_times)
                rec.retries = comp.retries

    def _collect(self, h: ReplicaHandle) -> None:
        """Pull newly-terminal completions off a replica."""
        done = [rec for rec in self.records.values()
                if rec.replica == h.idx and rec.rid in h.engine.completions]
        for rec in done:
            comp = h.engine.completions[rec.rid]
            self.completions[rec.rid] = comp
            self.counters[f"status_{comp.status}"] += 1
            if comp.status == "ok" \
                    and self.aot.stats["builds"] == rec.dispatch_builds:
                # compile-clean samples only: a service interval that
                # absorbed an executable build (cold start, new bucket)
                # would seed the EWMA orders of magnitude high and make
                # the deadline shed reject every tight-but-feasible
                # request on an otherwise idle fleet
                service = comp.finish_time - rec.dispatch_time
                self._ewma_service = service if self._ewma_service is None \
                    else 0.5 * self._ewma_service + 0.5 * service
            del self.records[rec.rid]

    def _failover(self, idx: int) -> None:
        """Requeue every request placed on dead replica ``idx`` from the
        router's mirrors (front, rid order — FCFS priority survives)."""
        stranded = sorted(
            (rec for rec in self.records.values() if rec.replica == idx),
            key=lambda r: r.rid, reverse=True)
        for rec in stranded:
            rec.replica = None
            rec.failovers += 1
            self.counters["failovers"] += 1
            if self.obs.tracer is not None:
                self.obs.mark("failover", rec.rid, track=self._track,
                              replica=idx, failovers=rec.failovers)
            if rec.failovers > self.rc.max_failovers:
                self._finish_local(
                    rec, "failed",
                    error=f"failover budget exhausted "
                          f"({rec.failovers - 1} migrations; replica {idx} "
                          f"died last)")
            else:
                self.queue.appendleft(rec)

    def _health_check(self) -> bool:
        """Step-budget liveness: a replica holding work that has not
        progressed for ``stall_budget`` ticks is dead to the router —
        whether it hung (injected stall) or is merely wedged, failover
        is the same."""
        detected = False
        for h in self.replicas:
            if h.state == "dead" or not h.engine.has_work():
                continue
            if self.tick - h.last_progress >= self.rc.stall_budget:
                self.counters["stalls_detected"] += 1
                self.obs.instant("stall_detected", track=self._track,
                                 replica=h.idx, tick=self.tick)
                self.kill(h.idx)
                detected = True
        return detected

    # ------------------------------------------------------------------
    # Invariants + stats
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Fleet-level conservation sweep (the router fuzzer runs this
        after every tick), then each non-dead replica's own sweep.  A
        failed sweep dumps the flight recorder (when attached) before
        re-raising — replica sweeps dump their own engine context first,
        then the fleet context lands in a second dump."""
        try:
            self._check_invariants()
        except AssertionError as e:
            self.obs.record("invariant_failure", router=self._track,
                            error=str(e))
            self.obs.dump("router_invariant_failure", context={
                "error": str(e),
                "tick": self.tick,
                "queue_depth": len(self.queue),
                "inflight_rids": sorted(self.records),
                "replica_states": [h.state for h in self.replicas],
                "counters": dict(self.counters),
            })
            raise

    def _check_invariants(self) -> None:
        self.obs.metrics.check()
        queued = {rec.rid for rec in self.queue}
        for rid, rec in self.records.items():
            if rec.replica is None:
                assert rid in queued, f"rid {rid} unplaced but not queued"
            else:
                h = self.replicas[rec.replica]
                assert h.state != "dead", f"rid {rid} placed on dead replica"
                e = h.engine
                assert rid in e.live or any(r.rid == rid for r in e.queue), \
                    f"rid {rid} missing from replica {h.idx}"
        overlap = set(self.completions) & set(self.records)
        assert not overlap, f"rids both terminal and in flight: {overlap}"
        n_status = sum(self.counters[f"status_{st}"] for st in STATUSES)
        assert n_status == len(self.completions), \
            f"status counters {n_status} != completions {len(self.completions)}"
        assert self.counters["submitted"] == \
            len(self.completions) + len(self.records), "requests lost"
        for h in self.replicas:
            if h.state != "dead":
                h.engine.check_invariants()
        if self.tier is not None:
            # the shared tier is checked per-replica against each
            # allocator; this sweeps it once more in case every replica
            # is dead (the spills must still be internally consistent)
            self.tier.check()

    @property
    def stats(self) -> dict:
        out = {
            **self.counters,
            "tick": self.tick,
            "queue_depth": len(self.queue),
            "inflight": len(self.records),
            "replica_states": [h.state for h in self.replicas],
            "replica_loads": [h.load() for h in self.replicas],
            **self.aot.stats,
            "executables": len(self.aot),
        }
        if self.tier is not None:
            out["host_tier"] = {
                "spilled_lanes": self.tier.spilled_lanes,
                "spilled_blocks": self.tier.spilled_blocks,
                "used_bytes": self.tier.used_bytes,
                "lane_spills": self.tier.lane_spills,
                "lane_restores": self.tier.lane_restores,
                "prefix_spills": self.tier.prefix_spills,
                "prefix_hits": self.tier.prefix_hits,
                "drops": self.tier.drops,
            }
        if self.faults is not None:
            out["faults"] = self.faults.stats()
        return out
