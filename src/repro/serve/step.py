"""Serving step builders: prefill and decode as separately-jitted programs.

``serve_step`` for the dry-run shapes means: decode shapes lower
``decode_step`` (one new token against a seq_len cache), prefill shapes
lower ``prefill``.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models.common import ShardRules
from repro.train.step import shardings_for


def jit_prefill(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                shape: ShapeConfig, *, max_len: int | None = None):
    mod = registry.get_module(cfg)

    def fn(params, tokens, extra):
        return mod.prefill(cfg, mesh, rules, params, tokens, extra,
                           max_len=max_len)

    params_sds = registry.abstract_params(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    in_sds, in_ps = registry.prefill_inputs(cfg, shape, rules)
    tok_sds = in_sds["tokens"]
    tok_sh = NamedSharding(mesh, in_ps["tokens"])
    extra_key = [k for k in in_sds if k != "tokens"]
    if extra_key:
        e_sds = in_sds[extra_key[0]]
        e_sh = NamedSharding(mesh, in_ps[extra_key[0]])
    else:
        e_sds, e_sh = None, None
    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, e_sh))
    return jitted, (params_sds, tok_sds, e_sds)


def jit_decode_step(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                    shape: ShapeConfig, *, donate: bool = True):
    mod = registry.get_module(cfg)

    def fn(params, cache, tokens, cur_index):
        return mod.decode_step(cfg, mesh, rules, params, cache, tokens, cur_index)

    params_sds = registry.abstract_params(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    cache_sds, cache_ps, tok_sds, tok_ps = registry.decode_inputs(cfg, shape, mesh)
    cache_sh = shardings_for(mesh, cache_ps)
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, cache_sh, NamedSharding(mesh, tok_ps), None),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, (params_sds, cache_sds, tok_sds, idx_sds)
