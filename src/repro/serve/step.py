"""Serving step builders: prefill and decode as separately-jitted programs.

``serve_step`` for the dry-run shapes means: decode shapes lower
``decode_step`` (one new token against a seq_len cache), prefill shapes
lower ``prefill``.

The program builders (``slot_decode_program`` / ``slot_prefill_program``
and their paged twins ``paged_decode_program`` / ``paged_prefill_program``)
are the continuous-batching engine's executables: decode advances every
lane of the cache by one token with sampling **fused on device** (the
host fetches one ``(max_slots,)`` int32 vector per step, not logits —
per-slot temperature/top-k/top-p ride in state vectors), prefill admits
one bucketed prompt — or, paged, one prefill *chunk* — into a lane and
seeds its slot state.  All are plain jitted functions; ``serve/engine.py``
AOT-compiles them through its :class:`~repro.core.aot.AotCache`.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models.attention import NEG_INF
from repro.models.common import ShardRules
from repro.train.step import shardings_for
from .faults import NONFINITE_TOKEN, UNCOMMITTED


def jit_prefill(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                shape: ShapeConfig, *, max_len: int | None = None):
    mod = registry.get_module(cfg)

    def fn(params, tokens, extra):
        return mod.prefill(cfg, mesh, rules, params, tokens, extra,
                           max_len=max_len)

    params_sds = registry.abstract_params(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    in_sds, in_ps = registry.prefill_inputs(cfg, shape, rules)
    tok_sds = in_sds["tokens"]
    tok_sh = NamedSharding(mesh, in_ps["tokens"])
    extra_key = [k for k in in_sds if k != "tokens"]
    if extra_key:
        e_sds = in_sds[extra_key[0]]
        e_sh = NamedSharding(mesh, in_ps[extra_key[0]])
    else:
        e_sds, e_sh = None, None
    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, e_sh))
    return jitted, (params_sds, tok_sds, e_sds)


def jit_decode_step(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                    shape: ShapeConfig, *, donate: bool = True):
    mod = registry.get_module(cfg)

    def fn(params, cache, tokens, cur_index):
        return mod.decode_step(cfg, mesh, rules, params, cache, tokens, cur_index)

    params_sds = registry.abstract_params(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    cache_sds, cache_ps, tok_sds, tok_ps = registry.decode_inputs(cfg, shape, mesh)
    cache_sh = shardings_for(mesh, cache_ps)
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, cache_sh, NamedSharding(mesh, tok_ps), None),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, (params_sds, cache_sds, tok_sds, idx_sds)


# ---------------------------------------------------------------------------
# Fused on-device sampling
# ---------------------------------------------------------------------------


def sample_tokens(logits, key, temps, top_k: int = 0, top_ks=None, top_ps=None):
    """Per-row sampling fused into the decode/prefill executables.

    logits: (B, V); temps: (B,) — rows with ``temp == 0`` take the argmax,
    rows with ``temp > 0`` sample ``categorical(logits / temp)``.  Masks,
    all optional and applied only in the stochastic branch:

      top_k    static int — one k for every row (the engine-static knob)
      top_ks   (B,) int32 — per-row k, ``0`` disables that row's mask
      top_ps   (B,) f32   — per-row nucleus threshold applied after
               temperature; ``<= 0`` or ``>= 1`` disables; the most
               probable token always survives

    Returns (B,) int32.  The stochastic branch (PRNG bits + sort-based
    masks over the full (B, V) logits) sits behind a ``lax.cond`` on
    ``any(temp > 0)`` so all-greedy steps pay only the argmax.
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def stochastic(_):
        z = logits
        V = z.shape[-1]
        if top_k:
            kth = jax.lax.top_k(z, top_k)[0][..., -1:]
            z = jnp.where(z < kth, NEG_INF, z)
        safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
        if top_ks is None and top_ps is None:
            zt = z / safe_t
        else:
            B = z.shape[0]
            ks = jnp.zeros(B, jnp.int32) if top_ks is None else top_ks
            ps = jnp.zeros(B, jnp.float32) if top_ps is None else top_ps

            def masked(zz):
                # ONE argsort serves both per-row masks: the descending
                # sort yields the k-th thresholds directly, and (positive
                # temperature preserving order) the nucleus exclusive
                # cumsum runs over the same permutation
                order = jnp.argsort(-zz, axis=-1)
                z_sorted = jnp.take_along_axis(zz, order, axis=-1)
                kth = jnp.take_along_axis(
                    z_sorted, jnp.clip(ks - 1, 0, V - 1)[:, None], axis=-1)
                drop_k = (ks > 0)[:, None] & (z_sorted < kth)
                p_sorted = jax.nn.softmax(
                    jnp.where(drop_k, NEG_INF, z_sorted) / safe_t, axis=-1)
                # drop tokens whose EXCLUSIVE cumulative probability
                # already reaches p: the smallest set covering p survives,
                # and the top token (exclusive cum = 0) always does
                drop_p = ((ps > 0) & (ps < 1))[:, None] & (
                    jnp.cumsum(p_sorted, axis=-1) - p_sorted >= ps[:, None])
                drop = jnp.take_along_axis(
                    drop_k | drop_p, jnp.argsort(order, axis=-1), axis=-1)
                return jnp.where(drop, NEG_INF, zz / safe_t)

            # all-default steps (no per-row masks anywhere) skip the sort
            need = jnp.any(ks > 0) | jnp.any((ps > 0) & (ps < 1))
            zt = jax.lax.cond(need, masked, lambda zz: zz / safe_t, z)
        sampled = jax.random.categorical(key, zt, axis=-1)
        return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)

    return jax.lax.cond(jnp.any(temps > 0), stochastic, lambda _: greedy, None)


# ---------------------------------------------------------------------------
# Slot programs (continuous batching)
# ---------------------------------------------------------------------------


def _decode_program(decode_fn, *, eos_id: int | None, fused: bool,
                    freeze=None):
    """Wrap a cache-layout-specific ``decode_fn(params, state) ->
    (logits, cache')`` with the shared scheduling/sampling bookkeeping.

    fused=True (the engine default): ``fn(params, state) -> (state', tok)``
    — sampling (per-slot temperature/top-k/top-p vectors), length
    bookkeeping, and EOS/budget eviction all happen on device; ``tok`` is
    the only per-step host fetch.

    fused=False (benchmark baseline): ``fn(params, state) -> (state', logits)``
    — full logits round-trip to the host, which samples and writes
    ``tokens``/``active`` back before the next step (the old loop's cost).

    ``freeze(cache, active) -> cache`` (recurrent state kinds): applied to
    the post-step cache with the post-step ``active`` vector, zeroing the
    recurrent leaves of inactive lanes — evict-time zeroing fused into
    the decode executable (see :class:`repro.serve.cache.RecurrentCache`).
    """

    def fn(params, state):
        key, sub = jax.random.split(state["key"])
        logits, cache = decode_fn(params, state)
        active = state["active"]
        new_len = state["lengths"] + active.astype(jnp.int32)
        if not fused:
            # host sampling: eviction lands by host push before the next
            # step, so inactive lanes zero one executable later
            if freeze is not None:
                cache = freeze(cache, active | state["replay"])
            new_state = {**state, "cache": cache, "lengths": new_len, "key": key}
            return new_state, logits
        tok = sample_tokens(
            logits, sub, state["temps"],
            top_ks=state["top_ks"], top_ps=state["top_ps"],
        )
        # Non-finite detection rides the SAME (max_slots,) token fetch the
        # host already reads (vocab ids are >= 0, so NONFINITE_TOKEN is
        # unambiguous — no extra sync).  A bad lane is neither finished
        # nor zeroed on device: the host owns the verdict (quarantine +
        # bounded retry through preempt-and-requeue, or terminal failure).
        finite = jnp.all(jnp.isfinite(logits), axis=-1)
        tok = jnp.where(active, tok, 0).astype(jnp.int32)
        tok = jnp.where(active & ~finite, jnp.int32(NONFINITE_TOKEN), tok)
        done = active & finite & (new_len >= state["limits"])
        if eos_id is not None:
            done |= active & (tok == eos_id)
        act_new = active & ~done
        if freeze is not None:
            # a replaying lane's "done" is advisory (the host forces the
            # RECORDED token and may keep the lane alive — e.g. a spurious
            # EOS resampled at a different key position): keep its state
            cache = freeze(cache, act_new | state["replay"])
        new_state = {
            **state, "cache": cache, "tokens": tok, "lengths": new_len,
            "active": act_new, "key": key,
        }
        return new_state, tok

    return fn


def slot_decode_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                        eos_id: int | None = None, fused: bool = True):
    """One decode step over every lane of the slotted cache.

    Family-generic: ``mod.decode_step`` advances a KV cache (lm), a pure
    per-lane recurrent state (ssm/xlstm — ``lengths`` rides along as the
    logical position but the state is O(1) in it), or zamba's composed
    hybrid cache.  Recurrent leaves of inactive lanes are zeroed on the
    way out (:class:`~repro.serve.cache.RecurrentCache.freeze`)."""
    from .cache import RecurrentCache

    mod = registry.get_module(cfg)
    rec = RecurrentCache(cfg)

    def decode_fn(params, state):
        return mod.decode_step(
            cfg, mesh, rules, params, state["cache"],
            state["tokens"], state["lengths"],
        )

    return _decode_program(decode_fn, eos_id=eos_id, fused=fused,
                           freeze=rec.freeze if rec else None)


def paged_decode_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                         eos_id: int | None = None, fused: bool = True,
                         impl: str = "ref"):
    """One decode step over every lane of the paged (block-table) cache.
    Identical bookkeeping to :func:`slot_decode_program`; only the cache
    walk differs (``decode_step_paged`` through ``state["tables"]``)."""
    mod = registry.get_module(cfg)

    def decode_fn(params, state):
        return mod.decode_step_paged(
            cfg, mesh, rules, params, state["cache"],
            state["tokens"], state["lengths"], state["tables"], impl=impl,
        )

    return _decode_program(decode_fn, eos_id=eos_id, fused=fused)


def paged_copy_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules):
    """Copy one physical KV block of the paged pool — the prefix cache's
    copy-on-write step (see :func:`repro.models.lm.copy_paged_block`).

    ``fn(state, src, dst) -> state'`` with ``src``/``dst`` traced scalars:
    one AOT executable serves every COW regardless of which blocks are
    involved.
    """
    mod = registry.get_module(cfg)

    def fn(state, src, dst):
        cache = mod.copy_paged_block(cfg, state["cache"], src, dst)
        return {**state, "cache": cache}

    return fn


def slot_prefill_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                         eos_id: int | None = None, fused: bool = True):
    """Admit one prompt into lane ``slot``: prefill its KV into the lane
    (prompt padded to a length bucket; one executable per bucket), sample
    the first generated token, and seed the slot's scheduling state
    (including its per-slot sampling params).

    ``fn(params, state, prompt (1, bucket), slot, plen, limit, temp,
    top_k, top_p) -> (state', tok (1,))`` with fused sampling, or
    ``-> (state', logits)`` when ``fused=False`` (host samples and writes
    tokens/active back).

    Family-generic like :func:`slot_decode_program`: ``mod.prefill_slot``
    writes a KV lane slice (lm), a per-lane recurrent snapshot at
    position ``plen`` (ssm/xlstm), or both (zamba).  Recurrent leaves are
    re-zeroed for inactive lanes on the way out, so a request that
    finishes *at admission* (budget 1 / instant EOS) leaves its lane
    clean.
    """
    from .cache import RecurrentCache

    mod = registry.get_module(cfg)
    rec = RecurrentCache(cfg)

    def fn(params, state, prompt, slot, plen, limit, temp, top_k, top_p):
        key, sub = jax.random.split(state["key"])
        cache, logits = mod.prefill_slot(
            cfg, mesh, rules, params, state["cache"], prompt, slot, plen,
        )
        upd = lambda a, v: a.at[slot].set(jnp.asarray(v).astype(a.dtype))
        new_state = {
            **state,
            "cache": cache,
            "lengths": upd(state["lengths"], plen),
            "limits": upd(state["limits"], limit),
            "temps": upd(state["temps"], temp),
            "top_ks": upd(state["top_ks"], top_k),
            "top_ps": upd(state["top_ps"], top_p),
            "key": key,
        }
        # evict-time zeroing for OTHER lanes only: the slot being prefilled
        # must keep its fresh state even if its sampled token reads as done
        # — a preempted lane's resume forces the RECORDED token host-side
        # and keeps decoding, so zeroing on a (possibly resampled) EOS here
        # would destroy the state the replay is about to advance.  A lane
        # that really finishes at admission is zeroed by the next
        # executable's freeze instead (one-executable lag).  ``replay``
        # lanes are protected here exactly as in the decode program: a
        # mid-replay lane's device ``active`` bit can be stale-False (a
        # spurious EOS the host overrides only at the next sched push,
        # which happens AFTER admissions run), and an admission prefill
        # in that window must not zero the state the replay will advance.
        keep_self = jnp.arange(state["active"].shape[0]) == slot
        keep = state["replay"] | keep_self
        if not fused:
            new_state["active"] = upd(state["active"], plen < limit)
            if rec:
                new_state["cache"] = rec.freeze(
                    new_state["cache"], new_state["active"] | keep)
            return new_state, logits
        tok = sample_tokens(
            logits, sub, jnp.reshape(temp, (1,)),
            top_ks=jnp.reshape(top_k, (1,)), top_ps=jnp.reshape(top_p, (1,)),
        )
        # non-finite logits report the sentinel token (see _decode_program)
        finite = jnp.all(jnp.isfinite(logits))
        tok = jnp.where(finite, tok, jnp.int32(NONFINITE_TOKEN))
        alive = (plen < limit) & finite
        if eos_id is not None:
            alive &= tok[0] != eos_id
        new_state["tokens"] = upd(state["tokens"], tok[0])
        new_state["active"] = upd(state["active"], alive)
        if rec:
            new_state["cache"] = rec.freeze(
                new_state["cache"], new_state["active"] | keep)
        return new_state, tok

    return fn


def paged_prefill_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                          eos_id: int | None = None, fused: bool = True,
                          first: bool = True):
    """Process ONE prefill chunk of a request in lane ``slot`` of the
    paged cache — chunked prefill's unit of work, also the whole-prompt
    admission when the chunk is the full bucket.

    ``first=True`` (static): the chunk starts at position 0 and runs the
    plain ``forward`` (bitwise-identical to the slotted prefill) —
    ``start`` is ignored.  ``first=False``: continuation through
    ``prefill_chunk_paged`` at traced offset ``start``.  One executable
    per (chunk size, first?) pair.

    ``fn(params, state, chunk (1, C), slot, start, plen, limit, temp,
    top_k, top_p) -> (state', tok (1,))``.  Scheduling state advances
    every chunk (``lengths`` = prefilled positions); the lane only
    activates — and the returned token is only meaningful — on the chunk
    that covers position ``plen - 1``.
    """
    mod = registry.get_module(cfg)

    def fn(params, state, chunk, slot, start, plen, limit, temp, top_k, top_p):
        key, sub = jax.random.split(state["key"])
        table_row = state["tables"][slot]
        if first:
            cache, logits = mod.prefill_slot_paged(
                cfg, mesh, rules, params, state["cache"], chunk, table_row,
                plen,
            )
            start = jnp.int32(0)
        else:
            cache, logits = mod.prefill_chunk_paged(
                cfg, mesh, rules, params, state["cache"], chunk, table_row,
                start, plen,
            )
        C = chunk.shape[1]
        end = jnp.minimum(start + C, plen)
        is_last = end >= plen
        upd = lambda a, v: a.at[slot].set(jnp.asarray(v).astype(a.dtype))
        new_state = {
            **state,
            "cache": cache,
            "lengths": upd(state["lengths"], end),
            "limits": upd(state["limits"], limit),
            "temps": upd(state["temps"], temp),
            "top_ks": upd(state["top_ks"], top_k),
            "top_ps": upd(state["top_ps"], top_p),
            "key": key,
        }
        if not fused:
            new_state["active"] = upd(
                state["active"], is_last & (plen < limit))
            return new_state, logits
        tok = sample_tokens(
            logits, sub, jnp.reshape(temp, (1,)),
            top_ks=jnp.reshape(top_k, (1,)), top_ps=jnp.reshape(top_p, (1,)),
        )
        # non-finite logits report the sentinel token (see _decode_program)
        finite = jnp.all(jnp.isfinite(logits))
        tok = jnp.where(finite, tok, jnp.int32(NONFINITE_TOKEN))
        alive = is_last & (plen < limit) & finite
        if eos_id is not None:
            alive &= tok[0] != eos_id
        new_state["tokens"] = upd(
            state["tokens"], jnp.where(is_last, tok[0], state["tokens"][slot]))
        new_state["active"] = upd(state["active"], alive)
        return new_state, tok

    return fn


# ---------------------------------------------------------------------------
# Speculative decoding (draft/verify)
# ---------------------------------------------------------------------------


def spec_decode_program(cfg: ArchConfig, dcfg: ArchConfig, mesh: Mesh,
                        rules: ShardRules, *, k: int,
                        eos_id: int | None = None, paged: bool = False,
                        impl: str = "ref"):
    """One speculative decode round over every lane: the draft model
    proposes ``k`` tokens per lane, the target scores all ``k + 1``
    positions, and each lane commits its accepted prefix — up to ``k + 1``
    tokens per dispatch instead of one.

    ``fn(params, dparams, state) -> (state', rows (max_slots, k+1) int32)``
    — the rows matrix is the ONLY host fetch: entry ``(lane, i)`` is the
    ``i``-th committed token of the lane's round, :data:`UNCOMMITTED`
    past the accepted prefix, or :data:`NONFINITE_TOKEN` for a committed
    position whose logits were non-finite (same quarantine contract as
    the plain decode program).

    Accept rule (greedy path): target step ``i`` consumes input ``u_i``
    (``u_0`` = the lane's pending token, ``u_i = draft_i`` after) at
    position ``lengths + i`` and samples ``y_i``; the chain stays valid
    while ``y_{i-1} == draft_{i-1}``, so every committed ``y_i`` is
    computed from exactly the committed token sequence — bitwise what
    the sequential engine would have sampled, no matter what the draft
    proposed.  The first mismatch commits the *target*'s ``y_i`` (the
    "resample" — for greedy, plain argmax) and invalidates the rest of
    the row.  Stochastic lanes draw per-position subkeys
    (``fold_in(sub, i)``); only the greedy path is bitwise-comparable to
    the sequential engine.

    State handling per kind:

    * **KV (slotted/paged)** — write-then-truncate: rejected positions
      hold junk KV past the commit point, lazily overwritten before the
      lane next attends them (the same argument as eviction; paged junk
      beyond the mapped horizon routes to the write sink, and shared
      prefix blocks are always fully committed so junk never lands in
      one — swept by ``check_invariants``).
    * **recurrent/hybrid leaves** — snapshot/rollback: ``keep`` tracks
      the state after the lane's last *committed* step and is restored
      wholesale on the way out (:meth:`RecurrentCache.rollback`), so a
      rejecting lane's state is bitwise the state before the rejected
      steps ran.

    The draft runs ``k + 1`` steps (the last consumes its own final
    proposal) so its KV covers positions ``lengths .. lengths + k`` —
    no gap when a lane accepts everything.  Recurrent draft leaves
    select the snapshot after step ``c_len - 1``, i.e. having consumed
    exactly the committed sequence minus the new pending token.

    Replaying lanes commit exactly ONE token per round (``valid`` drops
    them after step 0): the host forces each recorded token between
    dispatches, so speculating past the forced token would verify
    against inputs the host is about to override.
    """
    from .cache import RecurrentCache

    if k < 1:
        raise ValueError(f"spec_k must be >= 1, got {k}")
    mod = registry.get_module(cfg)
    dmod = registry.get_module(dcfg)
    rec = RecurrentCache(cfg)
    drec = RecurrentCache(dcfg)

    def target_step(params, state, cache, tok, pos):
        if paged:
            return mod.decode_step_paged(
                cfg, mesh, rules, params, cache, tok, pos,
                state["tables"], impl=impl)
        return mod.decode_step(cfg, mesh, rules, params, cache, tok, pos)

    def fn(params, dparams, state):
        key, sub = jax.random.split(state["key"])
        active = state["active"]
        replay = state["replay"]
        lengths = state["lengths"]
        B = active.shape[0]

        # --- draft pass: k proposals + one covering step ---------------
        dcache = state["draft"]
        drafts, dstates = [], []
        z = state["tokens"]
        for i in range(k + 1):
            dlogits, dcache = dmod.decode_step(
                dcfg, mesh, rules, dparams, dcache, z, lengths + i)
            if drec:
                dstates.append(drec.snapshot(dcache))
            if i < k:
                z = jnp.argmax(
                    dlogits.astype(jnp.float32), axis=-1).astype(jnp.int32)
                drafts.append(z)

        # --- target verify ladder --------------------------------------
        cache = state["cache"]
        keep = rec.snapshot(cache) if rec else None
        valid = active
        c_len = jnp.zeros(B, jnp.int32)
        last_tok = jnp.zeros(B, jnp.int32)
        any_done = jnp.zeros(B, bool)
        rows = []
        u = state["tokens"]
        for i in range(k + 1):
            logits, cache = target_step(params, state, cache, u, lengths + i)
            tok = sample_tokens(
                logits, jax.random.fold_in(sub, i), state["temps"],
                top_ks=state["top_ks"], top_ps=state["top_ps"])
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            y = jnp.where(finite, tok, jnp.int32(NONFINITE_TOKEN)).astype(
                jnp.int32)
            done = finite & (lengths + i + 1 >= state["limits"])
            if eos_id is not None:
                done |= finite & (y == eos_id)
            committed = valid & finite
            rows.append(jnp.where(valid, y, jnp.int32(UNCOMMITTED)))
            last_tok = jnp.where(committed, y, last_tok)
            c_len = c_len + committed.astype(jnp.int32)
            any_done |= committed & done
            if rec:
                keep = rec.snapshot(rec.rollback(cache, keep, committed))
            if i < k:
                valid = valid & finite & ~done & ~replay & (y == drafts[i])
                u = drafts[i]

        act_new = active & ~any_done
        if rec:
            cache = {**cache, **keep}
            cache = rec.freeze(cache, act_new | replay)
        if drec:
            dsel = dstates[0]
            for j in range(1, k + 1):
                dsel = drec.snapshot(
                    drec.rollback({**dcache, **dstates[j]}, dsel, c_len > j))
            dcache = {**dcache, **dsel}
            dcache = drec.freeze(dcache, act_new | replay)

        new_state = {
            **state, "cache": cache, "draft": dcache,
            "tokens": jnp.where(active, last_tok, 0).astype(jnp.int32),
            "lengths": lengths + c_len,
            "active": act_new, "key": key,
        }
        return new_state, jnp.stack(rows, axis=1)

    return fn


def spec_draft_prefill_program(dcfg: ArchConfig, mesh: Mesh,
                               rules: ShardRules):
    """Seed the DRAFT model's lane from a token history: prefill
    ``hist`` (the prompt plus every committed token except the pending
    one, padded to a bucket) into draft lane ``slot``.

    ``fn(dparams, state, hist (1, bucket), slot, plen) -> state'`` —
    runs at admission and on every restore path (prefix-chain, host-tier,
    held-lane release).  The draft state it builds is *not* bitwise the
    state a decode-origin draft would have — it doesn't need to be:
    committed tokens never depend on draft values, only the accepted
    chain LENGTH does, so rebuilding the draft from history preserves
    output parity exactly.

    Deliberately NO freeze here: the device ``active`` vector can be
    stale mid-admission (the host batches scheduling pushes), so a
    freeze keyed on it could zero a lane another restore seeded moments
    earlier in the same engine step.  Inactive-lane draft zeroing is the
    spec decode program's job — it freezes the draft side every step,
    which is exactly when the invariant sweep checks it.
    """
    dmod = registry.get_module(dcfg)

    def fn(dparams, state, hist, slot, plen):
        dcache, _ = dmod.prefill_slot(
            dcfg, mesh, rules, dparams, state["draft"], hist, slot, plen)
        return {**state, "draft": dcache}

    return fn


# ---------------------------------------------------------------------------
# Host-tier spill/restore transport
# ---------------------------------------------------------------------------
#
# Four fixed-shape programs move lane state between the device cache and
# the host tier.  Shapes are independent of WHICH block/lane moves (the
# index is a traced scalar), so one AOT executable each serves every
# spill and every restore — the transport is builds-flat like the decode
# path, and the steady_builds_delta gates cover tiered modes too.


def paged_block_read_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules):
    """Read one physical block out of every paged-cache leaf.

    ``fn(state, block) -> {leaf: (bs, Hk, dh)-ish}`` — the replicated
    outputs are fetched to host (``np.asarray``) and become one
    :class:`~repro.serve.paged.LaneSpill` payload block (or a spilled
    prefix block).  Leaves are (L[,2], NB, bs, Hk, dh); the block axis is
    ``ndim - 4`` (see :func:`repro.models.lm.copy_paged_block`).
    """

    def fn(state, block):
        def rd(c):
            return jax.lax.dynamic_index_in_dim(
                c, block, c.ndim - 4, keepdims=False)

        return {name: rd(c) for name, c in state["cache"].items()}

    return fn


def paged_block_write_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules):
    """Write one physical block of every paged-cache leaf from host
    payloads — the restore half of :func:`paged_block_read_program`.

    ``fn(state, payload, block) -> state'`` with ``payload`` the
    ``{leaf: block}`` tree a spill captured.
    """

    def fn(state, payload, block):
        def wr(c, row):
            return jax.lax.dynamic_update_index_in_dim(
                c, row.astype(c.dtype), block, c.ndim - 4)

        cache = {
            name: wr(c, payload[name]) for name, c in state["cache"].items()
        }
        return {**state, "cache": cache}

    return fn


def lane_read_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                      axes: dict):
    """Read one lane's slice of every slot-cache leaf in ``axes``
    (``registry.lane_leaf_axes``: slotted KV segments and/or recurrent
    leaves — whatever the family says a lane owns).

    ``fn(state, slot) -> {leaf: lane slice}``; outputs are fetched to a
    ``kind == "lane"`` :class:`~repro.serve.paged.LaneSpill`.
    """

    def fn(state, slot):
        return {
            name: jax.lax.dynamic_index_in_dim(
                state["cache"][name], slot, axes[name], keepdims=False)
            for name in axes
        }

    return fn


def lane_write_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                       axes: dict):
    """Write one lane's slice of every slot-cache leaf from host payloads
    — the restore half of :func:`lane_read_program`.

    ``fn(state, payload, slot) -> state'``.  For recurrent leaves this
    must be pushed *with the lane already marked active on device*: the
    prefill program's freeze zeroes inactive lanes, so the engine pushes
    schedule state immediately after a recurrent lane restore.
    """

    def fn(state, payload, slot):
        cache = dict(state["cache"])
        for name, axis in axes.items():
            c = cache[name]
            cache[name] = jax.lax.dynamic_update_index_in_dim(
                c, payload[name].astype(c.dtype), slot, axis)
        return {**state, "cache": cache}

    return fn
