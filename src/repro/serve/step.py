"""Serving step builders: prefill and decode as separately-jitted programs.

``serve_step`` for the dry-run shapes means: decode shapes lower
``decode_step`` (one new token against a seq_len cache), prefill shapes
lower ``prefill``.

The slot-program builders (``slot_decode_program`` / ``slot_prefill_program``)
are the continuous-batching engine's executables: decode advances every
lane of the slotted cache by one token with sampling **fused on device**
(the host fetches one ``(max_slots,)`` int32 vector per step, not logits),
prefill admits one bucketed prompt into a lane and seeds its slot state.
Both are plain jitted functions; ``serve/engine.py`` AOT-compiles them
through its :class:`~repro.core.aot.AotCache`.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models.attention import NEG_INF
from repro.models.common import ShardRules
from repro.train.step import shardings_for


def jit_prefill(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                shape: ShapeConfig, *, max_len: int | None = None):
    mod = registry.get_module(cfg)

    def fn(params, tokens, extra):
        return mod.prefill(cfg, mesh, rules, params, tokens, extra,
                           max_len=max_len)

    params_sds = registry.abstract_params(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    in_sds, in_ps = registry.prefill_inputs(cfg, shape, rules)
    tok_sds = in_sds["tokens"]
    tok_sh = NamedSharding(mesh, in_ps["tokens"])
    extra_key = [k for k in in_sds if k != "tokens"]
    if extra_key:
        e_sds = in_sds[extra_key[0]]
        e_sh = NamedSharding(mesh, in_ps[extra_key[0]])
    else:
        e_sds, e_sh = None, None
    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, e_sh))
    return jitted, (params_sds, tok_sds, e_sds)


def jit_decode_step(cfg: ArchConfig, mesh: Mesh, rules: ShardRules,
                    shape: ShapeConfig, *, donate: bool = True):
    mod = registry.get_module(cfg)

    def fn(params, cache, tokens, cur_index):
        return mod.decode_step(cfg, mesh, rules, params, cache, tokens, cur_index)

    params_sds = registry.abstract_params(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    cache_sds, cache_ps, tok_sds, tok_ps = registry.decode_inputs(cfg, shape, mesh)
    cache_sh = shardings_for(mesh, cache_ps)
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, cache_sh, NamedSharding(mesh, tok_ps), None),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, (params_sds, cache_sds, tok_sds, idx_sds)


# ---------------------------------------------------------------------------
# Fused on-device sampling
# ---------------------------------------------------------------------------


def sample_tokens(logits, key, temps, top_k: int = 0):
    """Per-row sampling fused into the decode/prefill executables.

    logits: (B, V); temps: (B,) — rows with ``temp == 0`` take the argmax,
    rows with ``temp > 0`` sample ``categorical(logits / temp)`` (after an
    optional static top-k mask).  Returns (B,) int32.

    The stochastic branch (PRNG bits over the full (B, V) logits) sits
    behind a ``lax.cond`` on ``any(temp > 0)`` so all-greedy steps pay
    only the argmax.
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def stochastic(_):
        z = logits
        if top_k:
            kth = jax.lax.top_k(z, top_k)[0][..., -1:]
            z = jnp.where(z < kth, NEG_INF, z)
        safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
        sampled = jax.random.categorical(key, z / safe_t, axis=-1)
        return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)

    return jax.lax.cond(jnp.any(temps > 0), stochastic, lambda _: greedy, None)


# ---------------------------------------------------------------------------
# Slot programs (continuous batching)
# ---------------------------------------------------------------------------


def slot_decode_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                        top_k: int = 0, eos_id: int | None = None,
                        fused: bool = True):
    """One decode step over every lane of the slotted cache.

    fused=True (the engine default): ``fn(params, state) -> (state', tok)``
    — sampling, length bookkeeping, and EOS/budget eviction all happen on
    device; ``tok`` is the only per-step host fetch.

    fused=False (benchmark baseline): ``fn(params, state) -> (state', logits)``
    — full logits round-trip to the host, which samples and writes
    ``tokens``/``active`` back before the next step (the old loop's cost).
    """
    mod = registry.get_module(cfg)

    def fn(params, state):
        key, sub = jax.random.split(state["key"])
        logits, cache = mod.decode_step(
            cfg, mesh, rules, params, state["cache"],
            state["tokens"], state["lengths"],
        )
        active = state["active"]
        new_len = state["lengths"] + active.astype(jnp.int32)
        if not fused:
            new_state = {**state, "cache": cache, "lengths": new_len, "key": key}
            return new_state, logits
        tok = sample_tokens(logits, sub, state["temps"], top_k)
        tok = jnp.where(active, tok, 0).astype(jnp.int32)
        done = active & (new_len >= state["limits"])
        if eos_id is not None:
            done |= active & (tok == eos_id)
        new_state = {
            **state, "cache": cache, "tokens": tok, "lengths": new_len,
            "active": active & ~done, "key": key,
        }
        return new_state, tok

    return fn


def slot_prefill_program(cfg: ArchConfig, mesh: Mesh, rules: ShardRules, *,
                         top_k: int = 0, eos_id: int | None = None,
                         fused: bool = True):
    """Admit one prompt into lane ``slot``: prefill its KV into the lane
    (prompt padded to a length bucket; one executable per bucket), sample
    the first generated token, and seed the slot's scheduling state.

    ``fn(params, state, prompt (1, bucket), slot, plen, limit, temp)
    -> (state', tok (1,))`` with fused sampling, or ``-> (state', logits)``
    when ``fused=False`` (host samples and writes tokens/active back).
    """
    mod = registry.get_module(cfg)

    def fn(params, state, prompt, slot, plen, limit, temp):
        key, sub = jax.random.split(state["key"])
        cache, logits = mod.prefill_slot(
            cfg, mesh, rules, params, state["cache"], prompt, slot, plen,
        )
        upd = lambda a, v: a.at[slot].set(jnp.asarray(v).astype(a.dtype))
        new_state = {
            **state,
            "cache": cache,
            "lengths": upd(state["lengths"], plen),
            "limits": upd(state["limits"], limit),
            "temps": upd(state["temps"], temp),
            "key": key,
        }
        if not fused:
            new_state["active"] = upd(state["active"], plen < limit)
            return new_state, logits
        tok = sample_tokens(logits, sub, jnp.reshape(temp, (1,)), top_k)
        alive = plen < limit
        if eos_id is not None:
            alive &= tok[0] != eos_id
        new_state["tokens"] = upd(state["tokens"], tok[0])
        new_state["active"] = upd(state["active"], alive)
        return new_state, tok

    return fn
