import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Multi-device semantics checks, run in a subprocess by the test suite
(the main pytest process must keep seeing 1 CPU device).

Usage: python -m repro.testing.md_checks <check_name | all>
Exits non-zero on failure.
"""
import sys

import numpy as np


def check_scatter_reduce():
    import jax.numpy as jnp
    import repro.core as synk

    ctx = synk.fork()
    assert ctx.n_data == 8, ctx.n_data

    def loss_fn(x, y, w):
        return jnp.mean((x @ w - y) ** 2)

    f = synk.function(loss_fn, [synk.Scatter(), synk.Scatter(), synk.Broadcast()],
                      synk.Reduce("mean"))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    y = rng.normal(size=(64,)).astype(np.float32)
    w = rng.normal(size=(8,)).astype(np.float32)
    want = np.mean((x @ w - y) ** 2)
    np.testing.assert_allclose(f(x, y, w), want, rtol=1e-5)
    # paper §5.1 invariant: sliced == unsliced
    np.testing.assert_allclose(f(x, y, w, num_slices=4), want, rtol=1e-5)
    # sum/max/min/concat
    for op, ref in [("sum", np.sum), ("max", np.max), ("min", np.min)]:
        g = synk.function(lambda x: getattr(jnp, op)(x), [synk.Scatter()],
                          synk.Reduce("mean" if False else op))
        got = g(x)
        if op == "sum":
            np.testing.assert_allclose(got, ref(x), rtol=1e-5)
        else:
            np.testing.assert_allclose(got, ref(x), rtol=1e-6)
    c = synk.function(lambda x: x * 3.0, [synk.Scatter()], synk.Reduce("concat"))
    np.testing.assert_allclose(np.asarray(c(x)), x * 3, rtol=1e-6)
    pw = synk.function(lambda x: jnp.sum(x), [synk.Scatter()], synk.Reduce(None))
    assert np.asarray(pw(x)).shape == (8,)
    np.testing.assert_allclose(np.asarray(pw(x)).sum(), x.sum(), rtol=1e-5)


def check_indexing():
    import jax.numpy as jnp
    import repro.core as synk

    synk.fork()
    rng = np.random.default_rng(1)
    x = rng.normal(size=(64, 4)).astype(np.float32)

    f = synk.function(lambda x: jnp.mean(x), [synk.Scatter()], synk.Reduce("mean"))
    dx = synk.data(x)
    idx = rng.permutation(64)[:32]
    np.testing.assert_allclose(f(dx, batch=idx), x[idx].mean(), rtol=1e-5)

    # device-resident (paper §4.2 + §5.2): GLOBAL row ids.  Aligned case:
    # each worker's index chunk references its own shard (fast local take).
    ds = synk.scatter_data(x)
    aligned = np.concatenate(
        [i * 8 + rng.permutation(8)[:4] for i in range(8)])
    got = f(ds, batch=aligned)
    np.testing.assert_allclose(got, x[aligned].mean(), rtol=1e-5)


def check_indexing_global():
    """Regression: global ``batch=`` ids that cross shard boundaries must
    read the right rows (the old code applied them to local shards
    verbatim, silently reading wrong rows for anything past worker 0)."""
    import jax.numpy as jnp
    import repro.core as synk

    ctx = synk.fork()
    assert ctx.n_data == 8
    rng = np.random.default_rng(7)
    x = rng.normal(size=(64, 4)).astype(np.float32)
    ds = synk.scatter_data(x)

    f = synk.function(lambda x: jnp.mean(x), [synk.Scatter()], synk.Reduce("mean"))
    # fully shuffled global indices: every chunk crosses shards
    idx = rng.permutation(64)[:32]
    np.testing.assert_allclose(f(ds, batch=idx), x[idx].mean(), rtol=1e-5)
    # repeated + reversed indices
    idx2 = np.asarray([63, 0, 0, 17, 40, 8, 55, 62] * 2)
    np.testing.assert_allclose(f(ds, batch=idx2), x[idx2].mean(), rtol=1e-5)

    # concat output: rows come back in request order, sliced to the
    # (pad-requiring) original length
    g = synk.function(lambda x: x * 1.0, [synk.Scatter()], synk.Reduce("concat"))
    idx3 = rng.permutation(64)[:12]            # 12 % 8 != 0 -> padded
    out = np.asarray(g(ds, batch=idx3))
    assert out.shape == (12, 4), out.shape
    np.testing.assert_allclose(out, x[idx3], rtol=1e-6)

    # pad > len(idx) edge case: 2 indices over 8 workers
    idx4 = np.asarray([5, 60])
    out = np.asarray(g(ds, batch=idx4))
    assert out.shape == (2, 4), out.shape
    np.testing.assert_allclose(out, x[idx4], rtol=1e-6)

    # gspmd backend: same global semantics
    h = synk.function(lambda x: jnp.mean(x), [synk.Scatter()],
                      synk.Reduce("mean"), backend="gspmd")
    np.testing.assert_allclose(h(ds, batch=idx), x[idx].mean(), rtol=1e-5)


def check_bucketed_reduce():
    """Bucketed flat all-reduce == monolithic (bit-for-bit, fp32), and the
    reduce-scatter/all-gather pair round-trips exactly."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.optim.buckets import (
        bucketed_all_gather, bucketed_all_reduce, bucketed_reduce_scatter,
        make_buckets,
    )
    from repro.optim.flat import flatten, make_layout

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(3)
    tree = {
        "w": rng.normal(size=(129, 31)).astype(np.float32),
        "b": rng.normal(size=(977,)).astype(np.float32),
        "k": rng.normal(size=(3, 3, 3)).astype(np.float32),
    }
    layout = make_layout(tree)
    buckets = make_buckets(layout, bucket_bytes=2048, n_shards=8)
    assert buckets.num_buckets > 1

    def worker(seed):
        g = flatten(layout, tree) * (1.0 + seed[0])
        mono = jax.lax.pmean(g, "data")
        buck = bucketed_all_reduce(g, buckets, "data", op="mean")
        rs = bucketed_reduce_scatter(g, buckets, "data", op="mean")
        ag = bucketed_all_gather(rs, buckets, "data")
        return mono, buck, ag

    fn = jax.jit(compat.shard_map(
        worker, mesh=mesh, in_specs=P("data"), out_specs=(P(), P(), P()),
        check_vma=False,
    ))
    mono, buck, ag = fn(np.arange(8.0, dtype=np.float32))
    assert bool(jnp.all(mono == buck)), "bucketed != monolithic (bitwise)"
    np.testing.assert_allclose(np.asarray(ag), np.asarray(mono), rtol=1e-6)


def check_flat_parity():
    """Faithful flat-engine training (bucketed all-reduce + fused flat
    Adam) and the ZeRO flat path must both track the legacy GSPMD adam
    step loss-for-loss."""
    import jax
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import _mk
    from repro.models.common import ShardRules
    from repro.optim import OptConfig
    from repro.train.loop import init_sharded
    from repro.train.step import TrainSettings, jit_train_step

    cfg = get_smoke_config("smollm-360m")
    mesh = _mk((8, 1), ("data", "model"))
    shape = ShapeConfig("t", "train", 8, 16)   # (seq_len, global_batch)
    opt = OptConfig(kind="adam", lr=1e-3, bucket_mb=0.05)
    rng = np.random.default_rng(11)
    tokens = rng.integers(0, cfg.vocab, size=(16, 9)).astype(np.int32)

    def run(settings, rules, steps=3):
        stepf, _, in_sh = jit_train_step(
            cfg, mesh, rules, opt, shape, settings, donate=False)
        params, opt_state = init_sharded(cfg, mesh, rules, opt, 0, settings)
        batch = {"tokens": jax.device_put(tokens, in_sh[2]["tokens"])}
        losses = []
        for _ in range(steps):
            params, opt_state, m = stepf(params, opt_state, batch)
            losses.append(float(m["loss"]))
        return stepf._flat_engine, losses

    rules_f = ShardRules.for_mesh(mesh, faithful=True)
    mode_flat, flat = run(TrainSettings(faithful=True), rules_f)
    assert mode_flat == "faithful", mode_flat
    mode_leg, legacy = run(
        TrainSettings(faithful=True, flat_engine="off"), rules_f)
    assert mode_leg is None
    np.testing.assert_allclose(flat, legacy, rtol=2e-3)

    mode_z, zero = run(TrainSettings(flat_engine="zero"),
                       ShardRules.for_mesh(mesh))
    assert mode_z == "zero", mode_z
    np.testing.assert_allclose(zero, flat, rtol=2e-3)


def check_collectives():
    import repro.core as synk

    synk.fork()
    rng = np.random.default_rng(2)
    w = rng.normal(size=(6,)).astype(np.float32)
    params = synk.distribute({"w": w})
    params = synk.set_value(params, 3, {"w": w * 9})
    red = synk.all_reduce(params, "avg")
    expect = (w * 7 + w * 9) / 8
    for r in (0, 3, 7):
        np.testing.assert_allclose(synk.get_value(red, r)["w"], expect, rtol=1e-5)
    bc = synk.broadcast(params, root=3)
    np.testing.assert_allclose(synk.get_value(bc, 5)["w"], w * 9, rtol=1e-6)
    np.testing.assert_allclose(synk.as_replicated(bc)["w"], w * 9, rtol=1e-6)
    sc = synk.scatter_shared({"d": np.arange(16.0, dtype=np.float32)})
    np.testing.assert_allclose(
        synk.get_value(sc, 2)["d"], np.array([4.0, 5.0]), rtol=0)
    s = synk.all_reduce(params, "sum")
    np.testing.assert_allclose(synk.get_value(s, 0)["w"], w * 7 + w * 9, rtol=1e-5)


def check_sgd_parity():
    """Paper Appendix A: multi-GPU SGD with all-reduce(avg) must equal the
    serial single-device program."""
    import jax
    import jax.numpy as jnp
    import repro.core as synk

    synk.fork()
    rng = np.random.default_rng(3)
    X = rng.normal(size=(64, 8)).astype(np.float32)
    Y = (X @ rng.normal(size=(8,)) + 0.1).astype(np.float32)
    w0 = rng.normal(size=(8,)).astype(np.float32)
    lr = 0.05

    def grad_fn(x, y, w):
        return jax.grad(lambda w: jnp.mean((x @ w - y) ** 2))(w)

    # serial reference
    w_ref = w0.copy()
    for _ in range(5):
        g = np.asarray(grad_fn(jnp.asarray(X), jnp.asarray(Y), jnp.asarray(w_ref)))
        w_ref = w_ref - lr * g

    # synk program: local grads per worker, all-reduce(avg), local update
    f = synk.function(grad_fn, [synk.Scatter(), synk.Scatter(), synk.Broadcast()],
                      synk.Reduce("mean"))
    w = w0.copy()
    for _ in range(5):
        g = np.asarray(f(X, Y, w))
        w = w - lr * g
    np.testing.assert_allclose(w, w_ref, rtol=1e-5)


def check_elastic():
    """Checkpoint written under dp=8 restores under dp=4 (elastic)."""
    import tempfile

    import jax
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import _mk
    from repro.models.common import ShardRules
    from repro.optim import OptConfig
    from repro.train import LoopConfig, TrainSettings, train

    cfg = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", "train", 16, 8)
    opt = OptConfig(kind="adam", lr=1e-2)
    with tempfile.TemporaryDirectory() as d:
        mesh8 = _mk((8, 1), ("data", "model"))
        r8 = ShardRules.for_mesh(mesh8)
        res = train(cfg, shape, mesh8, r8, opt, TrainSettings(),
                    LoopConfig(steps=4, ckpt_every=4, ckpt_dir=d, log_every=0))
        mesh4 = _mk((4, 2), ("data", "model"))
        r4 = ShardRules.for_mesh(mesh4)
        res2 = train(cfg, shape, mesh4, r4, opt, TrainSettings(),
                     LoopConfig(steps=6, ckpt_every=6, ckpt_dir=d, log_every=0))
        assert np.isfinite(res2["final_loss"])
        assert res2["final_loss"] < res["final_loss"] + 1.0


CHECKS = {
    "scatter_reduce": check_scatter_reduce,
    "indexing": check_indexing,
    "indexing_global": check_indexing_global,
    "collectives": check_collectives,
    "sgd_parity": check_sgd_parity,
    "elastic": check_elastic,
    "bucketed_reduce": check_bucketed_reduce,
    "flat_parity": check_flat_parity,
}


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(CHECKS) if name == "all" else [name]
    for n in names:
        CHECKS[n]()
        print(f"[md_checks] {n} OK")


if __name__ == "__main__":
    main()
