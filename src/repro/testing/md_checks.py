import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Multi-device semantics checks, run in a subprocess by the test suite
(the main pytest process must keep seeing 1 CPU device).

Usage: python -m repro.testing.md_checks <check_name | all>
Exits non-zero on failure.
"""
import sys

import numpy as np


def check_scatter_reduce():
    import jax.numpy as jnp
    import repro.core as synk

    ctx = synk.fork()
    assert ctx.n_data == 8, ctx.n_data

    def loss_fn(x, y, w):
        return jnp.mean((x @ w - y) ** 2)

    f = synk.function(loss_fn, [synk.Scatter(), synk.Scatter(), synk.Broadcast()],
                      synk.Reduce("mean"))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    y = rng.normal(size=(64,)).astype(np.float32)
    w = rng.normal(size=(8,)).astype(np.float32)
    want = np.mean((x @ w - y) ** 2)
    np.testing.assert_allclose(f(x, y, w), want, rtol=1e-5)
    # paper §5.1 invariant: sliced == unsliced
    np.testing.assert_allclose(f(x, y, w, num_slices=4), want, rtol=1e-5)
    # sum/max/min/concat
    for op, ref in [("sum", np.sum), ("max", np.max), ("min", np.min)]:
        g = synk.function(lambda x: getattr(jnp, op)(x), [synk.Scatter()],
                          synk.Reduce("mean" if False else op))
        got = g(x)
        if op == "sum":
            np.testing.assert_allclose(got, ref(x), rtol=1e-5)
        else:
            np.testing.assert_allclose(got, ref(x), rtol=1e-6)
    c = synk.function(lambda x: x * 3.0, [synk.Scatter()], synk.Reduce("concat"))
    np.testing.assert_allclose(np.asarray(c(x)), x * 3, rtol=1e-6)
    pw = synk.function(lambda x: jnp.sum(x), [synk.Scatter()], synk.Reduce(None))
    assert np.asarray(pw(x)).shape == (8,)
    np.testing.assert_allclose(np.asarray(pw(x)).sum(), x.sum(), rtol=1e-5)


def check_indexing():
    import jax.numpy as jnp
    import repro.core as synk

    synk.fork()
    rng = np.random.default_rng(1)
    x = rng.normal(size=(64, 4)).astype(np.float32)

    f = synk.function(lambda x: jnp.mean(x), [synk.Scatter()], synk.Reduce("mean"))
    dx = synk.data(x)
    idx = rng.permutation(64)[:32]
    np.testing.assert_allclose(f(dx, batch=idx), x[idx].mean(), rtol=1e-5)

    # device-resident (paper §4.2 + §5.2): local indices against local shards
    ds = synk.scatter_data(x)
    local_idx = np.concatenate([rng.permutation(8)[:4] for _ in range(8)])
    got = f(ds, batch=local_idx)
    shards = x.reshape(8, 8, 4)
    want = np.mean([shards[i][local_idx[i * 4:(i + 1) * 4]] for i in range(8)])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def check_collectives():
    import repro.core as synk

    synk.fork()
    rng = np.random.default_rng(2)
    w = rng.normal(size=(6,)).astype(np.float32)
    params = synk.distribute({"w": w})
    params = synk.set_value(params, 3, {"w": w * 9})
    red = synk.all_reduce(params, "avg")
    expect = (w * 7 + w * 9) / 8
    for r in (0, 3, 7):
        np.testing.assert_allclose(synk.get_value(red, r)["w"], expect, rtol=1e-5)
    bc = synk.broadcast(params, root=3)
    np.testing.assert_allclose(synk.get_value(bc, 5)["w"], w * 9, rtol=1e-6)
    np.testing.assert_allclose(synk.as_replicated(bc)["w"], w * 9, rtol=1e-6)
    sc = synk.scatter_shared({"d": np.arange(16.0, dtype=np.float32)})
    np.testing.assert_allclose(
        synk.get_value(sc, 2)["d"], np.array([4.0, 5.0]), rtol=0)
    s = synk.all_reduce(params, "sum")
    np.testing.assert_allclose(synk.get_value(s, 0)["w"], w * 7 + w * 9, rtol=1e-5)


def check_sgd_parity():
    """Paper Appendix A: multi-GPU SGD with all-reduce(avg) must equal the
    serial single-device program."""
    import jax
    import jax.numpy as jnp
    import repro.core as synk

    synk.fork()
    rng = np.random.default_rng(3)
    X = rng.normal(size=(64, 8)).astype(np.float32)
    Y = (X @ rng.normal(size=(8,)) + 0.1).astype(np.float32)
    w0 = rng.normal(size=(8,)).astype(np.float32)
    lr = 0.05

    def grad_fn(x, y, w):
        return jax.grad(lambda w: jnp.mean((x @ w - y) ** 2))(w)

    # serial reference
    w_ref = w0.copy()
    for _ in range(5):
        g = np.asarray(grad_fn(jnp.asarray(X), jnp.asarray(Y), jnp.asarray(w_ref)))
        w_ref = w_ref - lr * g

    # synk program: local grads per worker, all-reduce(avg), local update
    f = synk.function(grad_fn, [synk.Scatter(), synk.Scatter(), synk.Broadcast()],
                      synk.Reduce("mean"))
    w = w0.copy()
    for _ in range(5):
        g = np.asarray(f(X, Y, w))
        w = w - lr * g
    np.testing.assert_allclose(w, w_ref, rtol=1e-5)


def check_elastic():
    """Checkpoint written under dp=8 restores under dp=4 (elastic)."""
    import tempfile

    import jax
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import _mk
    from repro.models.common import ShardRules
    from repro.optim import OptConfig
    from repro.train import LoopConfig, TrainSettings, train

    cfg = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", "train", 16, 8)
    opt = OptConfig(kind="adam", lr=1e-2)
    with tempfile.TemporaryDirectory() as d:
        mesh8 = _mk((8, 1), ("data", "model"))
        r8 = ShardRules.for_mesh(mesh8)
        res = train(cfg, shape, mesh8, r8, opt, TrainSettings(),
                    LoopConfig(steps=4, ckpt_every=4, ckpt_dir=d, log_every=0))
        mesh4 = _mk((4, 2), ("data", "model"))
        r4 = ShardRules.for_mesh(mesh4)
        res2 = train(cfg, shape, mesh4, r4, opt, TrainSettings(),
                     LoopConfig(steps=6, ckpt_every=6, ckpt_dir=d, log_every=0))
        assert np.isfinite(res2["final_loss"])
        assert res2["final_loss"] < res["final_loss"] + 1.0


CHECKS = {
    "scatter_reduce": check_scatter_reduce,
    "indexing": check_indexing,
    "collectives": check_collectives,
    "sgd_parity": check_sgd_parity,
    "elastic": check_elastic,
}


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(CHECKS) if name == "all" else [name]
    for n in names:
        CHECKS[n]()
        print(f"[md_checks] {n} OK")


if __name__ == "__main__":
    main()
