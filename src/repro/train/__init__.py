from .loop import LoopConfig, init_sharded, train
from .step import TrainSettings, build_train_step, jit_train_step, shardings_for

__all__ = [
    "LoopConfig", "init_sharded", "train",
    "TrainSettings", "build_train_step", "jit_train_step", "shardings_for",
]
