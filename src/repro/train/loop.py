"""Training loop with fault tolerance: checkpoint/restart, deterministic
data replay, and straggler-tolerant dispatch.

Under SPMD there is no per-worker straggler logic inside a step (the
compiler schedules every chip identically); the straggler surface is the
*host* side — input staging and checkpoint writes.  Both are overlapped:
batches for step t+1 are staged while step t runs (dispatch is async in
jax), and checkpoint saves run on a background thread.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data import make_batch_fn
from repro.models import registry
from repro.models.common import ShardRules
from repro.obs import Observer
from repro.optim import OptConfig
from repro.optim.buckets import make_buckets, reshard_scattered
from repro.train.step import (
    TrainSettings, flat_layout_for, jit_train_step, opt_state_template,
    shardings_for,
)


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep_k: int = 3
    log_every: int = 10
    seed: int = 0


def init_sharded(cfg: ArchConfig, mesh, rules: ShardRules, opt: OptConfig,
                 seed: int, settings: TrainSettings = TrainSettings()):
    mod = registry.get_module(cfg)
    p_sh = shardings_for(mesh, registry.param_pspecs(cfg, rules))
    params = jax.jit(
        lambda k: mod.init(cfg, k), out_shardings=p_sh
    )(jax.random.PRNGKey(seed))
    opt_init, o_pspecs = opt_state_template(cfg, mesh, rules, opt, settings)
    o_sh = shardings_for(mesh, o_pspecs)
    opt_state = jax.jit(opt_init, out_shardings=o_sh)(params)
    return params, opt_state


def train(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh,
    rules: ShardRules,
    opt: OptConfig,
    settings: TrainSettings = TrainSettings(),
    loop: LoopConfig = LoopConfig(),
    *,
    resume: bool = True,
    on_step: Callable[[int, dict], None] | None = None,
    obs: Observer | None = None,
) -> dict:
    """Runs the loop; returns final metrics summary.

    When an :class:`~repro.obs.Observer` is attached, every step records
    a ``step_ms`` histogram plus per-phase spans (``stage_batch`` /
    ``h2d`` / ``dispatch`` / ``device_wait`` / ``ckpt_save``) and the
    summary embeds the metrics snapshot.  NOTE: the ``device_wait`` span
    needs a ``block_until_ready`` on the step's metrics — profiling mode
    deliberately adds that one host sync per step (it is what separates
    host staging time from device compute); the untraced loop keeps the
    original fully-async dispatch."""
    step_fn, (params_sds, opt_sds, _), in_sh = jit_train_step(
        cfg, mesh, rules, opt, shape, settings
    )
    batch_fn = make_batch_fn(cfg, shape, loop.seed)
    b_sh = in_sh[2]

    mgr = (CheckpointManager(loop.ckpt_dir, loop.keep_k, obs=obs)
           if loop.ckpt_dir else None)
    # flat-engine provenance rides the checkpoint meta: a ZeRO
    # checkpoint's scattered m/v bake in (n_shards, bucket boundaries),
    # which a restore onto a different dp size must know to undo
    ckpt_meta = {"flat_engine": step_fn._flat_engine}
    if step_fn._flat_engine == "zero":
        ckpt_meta["zero_n_shards"] = step_fn._flat_buckets.n_shards
        ckpt_meta["zero_bucket_bytes"] = step_fn._flat_buckets.bucket_bytes
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        _, meta = mgr.load_meta()
        opt_tmpl, fix_opt = opt_sds, None
        if step_fn._flat_engine == "zero" \
                and meta.get("flat_engine") == "zero":
            new_b = step_fn._flat_buckets
            old_n = int(meta.get("zero_n_shards", new_b.n_shards))
            old_bb = int(meta.get("zero_bucket_bytes", new_b.bucket_bytes))
            if (old_n, old_bb) != (new_b.n_shards, new_b.bucket_bytes):
                # elastic ZeRO restore: read m/v at their CHECKPOINTED
                # scattered shapes, then reshard host-side for this dp
                old_b = make_buckets(
                    flat_layout_for(cfg), bucket_bytes=old_bb,
                    n_shards=old_n)
                old_sds = jax.ShapeDtypeStruct(
                    (old_b.scattered_total,), jax.numpy.float32)
                opt_tmpl = {**opt_sds, "m": old_sds, "v": old_sds}

                def fix_opt(state):
                    for k in ("m", "v"):
                        state[k] = reshard_scattered(state[k], old_b, new_b)
                print(f"[train] resharding ZeRO state dp={old_n} -> "
                      f"dp={new_b.n_shards}")
        start, state = mgr.restore({"params": params_sds, "opt": opt_tmpl})
        if fix_opt:
            fix_opt(state["opt"])
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state["params"], in_sh[0])
        opt_state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state["opt"], in_sh[1])
        print(f"[train] resumed from step {start}")
    else:
        params, opt_state = init_sharded(cfg, mesh, rules, opt, loop.seed, settings)

    losses, t0 = [], time.perf_counter()
    metrics = {}
    skipped = []   # per-step device scalars; summed once at the end
    traced = obs is not None and obs.tracer is not None
    step_hist = obs.metrics.histogram("step_ms") if obs is not None else None
    for step in range(start, loop.steps):
        ts = time.perf_counter()
        if traced:
            with obs.span("stage_batch", cat="train", track="train",
                          step=step):
                host_batch = batch_fn(step)
            with obs.span("h2d", cat="train", track="train"):
                batch = {k: jax.device_put(v, b_sh[k])
                         for k, v in host_batch.items()}
            with obs.span("dispatch", cat="train", track="train"):
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            # profiling-mode-only host sync: wait for the device so the
            # span boundary separates staging/dispatch from compute
            with obs.span("device_wait", cat="train", track="train"):
                jax.block_until_ready(metrics["loss"])
        else:
            host_batch = batch_fn(step)
            batch = {
                k: jax.device_put(v, b_sh[k]) for k, v in host_batch.items()
            }
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if "skipped" in metrics:
            skipped.append(metrics["skipped"])
        if loop.log_every and (step + 1) % loop.log_every == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            print(f"[train] step {step + 1:5d} loss {loss:.4f} ({dt:.1f}s)")
        if mgr and loop.ckpt_every and (step + 1) % loop.ckpt_every == 0:
            if traced:
                with obs.span("ckpt_save", cat="train", track="train",
                              step=step + 1):
                    mgr.save(step + 1, {"params": params, "opt": opt_state},
                             blocking=False, extra_meta=ckpt_meta)
            else:
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         blocking=False, extra_meta=ckpt_meta)
        if on_step:
            on_step(step, metrics)
        if step_hist is not None:
            step_hist.observe((time.perf_counter() - ts) * 1e3)
    if mgr:
        mgr.save(loop.steps, {"params": params, "opt": opt_state},
                 blocking=True, extra_meta=ckpt_meta)
        mgr.wait()
    out = {
        "final_loss": float(metrics["loss"]) if metrics else float("nan"),
        "losses": losses,
        # non-finite-gradient steps the flat engine turned into bitwise
        # no-ops (train/step.py skip_nonfinite); 0 off the flat paths
        "skipped_steps": int(sum(float(s) for s in skipped)),
        "params": params,
        "opt_state": opt_state,
    }
    if obs is not None:
        out["metrics"] = obs.metrics.snapshot()
    return out
