"""Train-step builder: loss -> grads -> update, with the paper's §5.1
input slicing (gradient accumulation), remat, ZeRO/FSDP or paper-faithful
replicated parameters, and donated buffers.

Two families of lowering:

* **Flat-gradient engine** (optim/buckets.py; engages on pure data-parallel
  meshes for adam/adamw) — the model runs as an explicit per-worker program
  under ``shard_map``; gradients are flattened into ONE fp32 buffer (paper
  §3.3) and reduced per ~4 MiB parameter-aligned bucket so the scheduler
  can overlap bucket collectives with remaining backward compute:

  - ``faithful=True``  — the paper's Appendix-A program: per-bucket
    all-reduce(mean), fused flat-Adam (Pallas kernel on TPU) on the
    replicated flat buffers.
  - ``flat_engine="zero"`` (with ``faithful=False``) — per-bucket
    reduce-scatter, sharded flat-Adam on the owned 1/N shard (ZeRO
    optimizer-state sharding: ``m``/``v`` are flat scattered buffers),
    per-bucket all-gather of updated parameters.

* **GSPMD path** (everything else: tensor/expert parallel meshes, MoE,
  non-adam rules) — ``jax.jit`` with sharded inputs; XLA places the
  collectives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models import common as common_mod
from repro.models.common import ShardRules
from repro.optim import OptConfig, apply_update, init_state, state_pspecs
from repro.optim.buckets import (
    BucketLayout,
    bucketed_all_gather,
    bucketed_all_reduce,
    bucketed_reduce_scatter,
    flat_adam_apply,
    make_buckets,
    resolve_bucket_bytes,
    scatter_flat,
)
from repro.optim.flat import FlatLayout, flatten, make_layout, unflatten

_DATA_AXIS_CANDIDATES = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    num_slices: int = 1          # paper §5.1 automated input slicing
    remat: Any = True            # False | True | "dots" (see common.remat_wrap)
    faithful: bool = False       # paper-faithful replicated-DP mode
    accum_dtype: str = "float32" # microbatch gradient accumulator dtype
    # Flat-gradient bucket engine:
    #   "auto" — faithful mode lowers to the bucketed flat program whenever
    #            the mesh is pure-DP and the rule is adam/adamw;
    #            non-faithful mode keeps the GSPMD per-parameter path.
    #   "zero" — non-faithful mode ALSO goes flat: bucketed reduce-scatter,
    #            sharded flat-Adam state, bucketed all-gather (ZeRO).
    #   "off"  — never use the flat engine.
    flat_engine: str = "auto"
    # None: Pallas flat_adam kernel on TPU, jnp reference elsewhere.
    flat_kernel: bool | None = None
    # Flat-engine non-finite gradient guard: when the reduced flat
    # gradient buffer holds any NaN/Inf, the step becomes a bitwise no-op
    # on params AND optimizer state (step counter included) — a loss
    # spike can then never poison the Adam moments.  The verdict is
    # computed on the post-reduction buffer (faithful) or psum'd across
    # shards (ZeRO), so every worker skips or applies in lockstep.
    # Surfaced as metrics["skipped"]; the loop counts skipped_steps.
    skip_nonfinite: bool = True


def data_axes_of(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in _DATA_AXIS_CANDIDATES)


def flat_engine_mode(
    cfg: ArchConfig, mesh: Mesh, opt: OptConfig, settings: TrainSettings,
) -> str | None:
    """Which flat-engine program this (cfg, mesh, opt, settings) lowers to:
    ``"faithful"`` | ``"zero"`` | ``None`` (GSPMD path).

    ``flat_engine="auto"`` degrades silently (faithful mode uses the flat
    program whenever it can, everything else falls back to GSPMD), but an
    EXPLICIT ``flat_engine="zero"`` request raises when it cannot engage —
    silently handing back unsharded optimizer state would defeat the
    memory plan the caller asked for.
    """
    if settings.flat_engine not in ("auto", "zero", "off"):
        raise ValueError(f"flat_engine {settings.flat_engine!r}")
    if settings.flat_engine == "off":
        return None
    want_zero = settings.flat_engine == "zero"

    def unavailable(reason: str):
        if want_zero:
            raise ValueError(f"flat_engine='zero' unavailable: {reason}")
        return None

    if opt.kind not in ("adam", "adamw"):
        return unavailable(f"requires adam/adamw, got {opt.kind!r}")
    daxes = data_axes_of(mesh)
    if not daxes:
        return unavailable("mesh has no data-parallel axes")
    # pure data-parallel only: with a live model axis the per-parameter
    # shardings carry tensor-parallel structure a flat buffer would destroy
    if any(mesh.shape[a] > 1 for a in mesh.axis_names if a not in daxes):
        return unavailable("mesh has a live model axis")
    # MoE loss paths shard_map internally (models/moe.py) and cannot nest
    if cfg.family == "moe":
        return unavailable("MoE loss paths shard_map internally")
    if settings.faithful:
        if want_zero:
            raise ValueError(
                "flat_engine='zero' conflicts with faithful=True "
                "(faithful replicates optimizer state by definition)"
            )
        return "faithful"
    if want_zero:
        if len(daxes) != 1:
            # reduce-scatter over exactly one named axis (multi-axis
            # scatter ordering is version-dependent)
            return unavailable(
                f"needs exactly one data axis, mesh has {daxes}")
        return "zero"
    return None


def _split_batch(batch: dict, k: int) -> dict:
    def sp(x):
        b = x.shape[0]
        if b % k:
            raise ValueError(f"num_slices={k} must divide global batch {b}")
        return x.reshape((k, b // k) + x.shape[1:])

    return {n: sp(v) for n, v in batch.items()}


def _make_compute_grads(cfg, mesh, rules, settings):
    """Shared loss+grad (with §5.1 slicing) used by both lowerings."""
    mod = registry.get_module(cfg)

    def loss_for_grad(params, microbatch):
        loss, metrics = mod.loss_fn(
            cfg, mesh, rules, params, microbatch, remat=settings.remat
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def compute_grads(params, batch):
        k = settings.num_slices
        if k == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        slices = _split_batch(batch, k)
        adt = jnp.dtype(settings.accum_dtype)

        def body(carry, mb):
            loss_acc, m_acc, g_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(adt) / k, g_acc, grads
            )
            m_acc = jax.tree.map(lambda a, m: a + m / k, m_acc, metrics)
            return (loss_acc + loss / k, m_acc, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        probe = jax.eval_shape(
            lambda p, b: grad_fn(p, b)[0][1], params,
            jax.tree.map(lambda x: x[0], slices),
        )
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), probe)
        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.float32(0.0), m0, g0), slices
        )
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return loss, metrics, grads

    return compute_grads


def build_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardRules,
    opt: OptConfig,
    settings: TrainSettings = TrainSettings(),
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    The returned callable carries introspection attributes:
    ``_flat_engine`` (None | "faithful" | "zero"), and when flat,
    ``_flat_layout`` / ``_flat_buckets``.
    """
    mode = flat_engine_mode(cfg, mesh, opt, settings)
    if mode is not None:
        return _build_flat_train_step(cfg, mesh, rules, opt, settings, mode)

    compute_grads = _make_compute_grads(cfg, mesh, rules, settings)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, opt_metrics = apply_update(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    train_step._flat_engine = None
    return train_step


# ---------------------------------------------------------------------------
# Flat-gradient engine (paper §3.3 + bucketed collectives)
# ---------------------------------------------------------------------------


def flat_layout_for(cfg: ArchConfig) -> FlatLayout:
    return make_layout(registry.abstract_params(cfg))


def buckets_for(
    cfg: ArchConfig, mesh: Mesh, opt: OptConfig, *, n_shards: int = 1,
) -> BucketLayout:
    layout = flat_layout_for(cfg)
    return make_buckets(
        layout,
        bucket_bytes=resolve_bucket_bytes(opt.bucket_mb, group_size=n_shards),
        n_shards=n_shards,
    )


def _build_flat_train_step(cfg, mesh, rules, opt, settings, mode: str):
    compute_grads = _make_compute_grads(cfg, mesh, rules, settings)
    daxes = data_axes_of(mesh)
    n_data = int(np.prod([mesh.shape[a] for a in daxes], dtype=np.int64))
    layout = flat_layout_for(cfg)
    buckets = make_buckets(
        layout,
        bucket_bytes=resolve_bucket_bytes(opt.bucket_mb, group_size=n_data),
        n_shards=n_data if mode == "zero" else 1,
    )
    wd = opt.weight_decay if opt.kind == "adamw" else 0.0

    def _clip(gflat_sq_sum, g):
        norm = jnp.sqrt(gflat_sq_sum)
        scale = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(norm, 1e-12))
        return g * scale, norm

    def worker(params, opt_state, batch):
        with common_mod.manual_mode():
            loss, metrics, grads = compute_grads(params, batch)
        loss = jax.lax.pmean(loss, daxes)
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), metrics)
        gflat = flatten(layout, grads)
        step = opt_state["step"] + 1
        adam_kw = dict(
            lr=opt.lr, beta1=opt.beta1, beta2=opt.beta2, eps=opt.eps,
            weight_decay=wd, use_kernel=settings.flat_kernel,
        )

        if mode == "faithful":
            # Appendix A, bucketed: every worker ends with the full mean
            # gradient; update replicated flat p/m/v buffers in one pass.
            gflat = bucketed_all_reduce(gflat, buckets, daxes, op="mean")
            # skip-step verdict AFTER the all-reduce: one worker's NaN
            # poisons every worker's mean, so the check is globally
            # consistent with no extra collective
            ok = jnp.all(jnp.isfinite(gflat)) \
                if settings.skip_nonfinite else None
            if opt.grad_clip:
                gflat, gnorm = _clip(jnp.sum(jnp.square(gflat)), gflat)
                metrics = {**metrics, "grad_norm": gnorm}
            pflat = flatten(layout, params)
            mflat = flatten(layout, opt_state["m"])
            vflat = flatten(layout, opt_state["v"])
            p2, m2, v2 = flat_adam_apply(
                pflat, gflat, mflat, vflat, step, **adam_kw
            )
            if ok is not None:
                # bitwise no-op on skip: keep the pre-update buffers and
                # don't advance the Adam step counter (bias correction
                # must not decay across a skipped step)
                p2 = jnp.where(ok, p2, pflat)
                m2 = jnp.where(ok, m2, mflat)
                v2 = jnp.where(ok, v2, vflat)
                step = opt_state["step"] + ok.astype(step.dtype)
                metrics = {**metrics,
                           "skipped": 1.0 - ok.astype(jnp.float32)}
            new_params = unflatten(layout, p2)
            new_state = {
                "step": step,
                "m": unflatten(layout, m2, dtype=jnp.float32),
                "v": unflatten(layout, v2, dtype=jnp.float32),
            }
            return new_params, new_state, {"loss": loss, **metrics}

        # ZeRO: own 1/N of every bucket; m/v live scattered (flat, sharded)
        g_loc = bucketed_reduce_scatter(gflat, buckets, daxes[0], op="mean")
        # the scatter localizes a NaN to whichever shard owns that region,
        # so the skip verdict needs a psum'd count to stay in lockstep
        ok = None
        if settings.skip_nonfinite:
            bad = jax.lax.psum(
                jnp.sum((~jnp.isfinite(g_loc)).astype(jnp.int32)), daxes)
            ok = bad == 0
        if opt.grad_clip:
            g_loc, gnorm = _clip(
                jax.lax.psum(jnp.sum(jnp.square(g_loc)), daxes), g_loc
            )
            metrics = {**metrics, "grad_norm": gnorm}
        widx = jax.lax.axis_index(daxes[0])
        p_loc = scatter_flat(flatten(layout, params), buckets, widx)
        p2, m2, v2 = flat_adam_apply(
            p_loc, g_loc, opt_state["m"], opt_state["v"], step, **adam_kw
        )
        if ok is not None:
            # params reassemble through all-gather of the (unchanged)
            # shard — pure data movement, so the round trip is bitwise
            p2 = jnp.where(ok, p2, p_loc)
            m2 = jnp.where(ok, m2, opt_state["m"])
            v2 = jnp.where(ok, v2, opt_state["v"])
            step = opt_state["step"] + ok.astype(step.dtype)
            metrics = {**metrics, "skipped": 1.0 - ok.astype(jnp.float32)}
        new_params = unflatten(
            layout, bucketed_all_gather(p2, buckets, daxes[0])
        )
        new_state = {"step": step, "m": m2, "v": v2}
        return new_params, new_state, {"loss": loss, **metrics}

    if mode == "faithful":
        opt_in = P()
        opt_out = P()
    else:
        opt_in = {"step": P(), "m": P(daxes), "v": P(daxes)}
        opt_out = {"step": P(), "m": P(daxes), "v": P(daxes)}

    mapped = compat.shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(), opt_in, P(daxes)),
        out_specs=(P(), opt_out, P()),
        check_vma=False,
    )

    def train_step(params, opt_state, batch):
        return mapped(params, opt_state, batch)

    train_step._flat_engine = mode
    train_step._flat_layout = layout
    train_step._flat_buckets = buckets
    return train_step


# ---------------------------------------------------------------------------
# Optimizer-state construction (mode-aware: ZeRO flat state is scattered)
# ---------------------------------------------------------------------------


def opt_state_template(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardRules,
    opt: OptConfig,
    settings: TrainSettings = TrainSettings(),
):
    """Returns ``(init_fn(params) -> opt_state, state_pspecs_tree)``
    consistent with what :func:`build_train_step` will expect."""
    mode = flat_engine_mode(cfg, mesh, opt, settings)
    if mode == "zero":
        daxes = data_axes_of(mesh)
        n_data = int(np.prod([mesh.shape[a] for a in daxes], dtype=np.int64))
        buckets = buckets_for(cfg, mesh, opt, n_shards=n_data)
        n = buckets.scattered_total

        def init_fn(params):
            del params
            return {
                "step": jnp.zeros((), jnp.int32),
                "m": jnp.zeros((n,), jnp.float32),
                "v": jnp.zeros((n,), jnp.float32),
            }

        pspecs = {"step": P(), "m": P(daxes), "v": P(daxes)}
        return init_fn, pspecs
    p_pspecs = registry.param_pspecs(cfg, rules)
    return partial(init_state, opt), state_pspecs(opt, p_pspecs)


# ---------------------------------------------------------------------------
# Jitted assembly with shardings (the object the dry-run lowers)
# ---------------------------------------------------------------------------


def shardings_for(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def jit_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardRules,
    opt: OptConfig,
    shape: ShapeConfig,
    settings: TrainSettings = TrainSettings(),
    *,
    donate: bool = True,
):
    """Returns (jitted fn, (params_sds, opt_sds, batch_sds), in_shardings)."""
    step = build_train_step(cfg, mesh, rules, opt, settings)

    params_sds = registry.abstract_params(cfg)
    p_pspecs = registry.param_pspecs(cfg, rules)
    opt_init, o_pspecs = opt_state_template(cfg, mesh, rules, opt, settings)
    opt_sds = jax.eval_shape(opt_init, params_sds)
    batch_sds, b_pspecs = registry.train_inputs(cfg, shape, rules)

    in_sh = (
        shardings_for(mesh, p_pspecs),
        shardings_for(mesh, o_pspecs),
        shardings_for(mesh, b_pspecs),
    )
    out_sh = (in_sh[0], in_sh[1], None)
    jitted = jax.jit(
        step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=(0, 1) if donate else (),
    )
    jitted._flat_engine = getattr(step, "_flat_engine", None)
    jitted._flat_layout = getattr(step, "_flat_layout", None)
    jitted._flat_buckets = getattr(step, "_flat_buckets", None)
    return jitted, (params_sds, opt_sds, batch_sds), in_sh
