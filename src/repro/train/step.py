"""Train-step builder: loss -> grads -> update, with the paper's §5.1
input slicing (gradient accumulation), remat, ZeRO/FSDP or paper-faithful
replicated parameters, and donated buffers.

Two modes map to the paper:
* ``faithful=True``  — parameters replicated across the data axes (the
  paper's per-GPU copies); the gradient combine lowers to one all-reduce,
  exactly the Appendix-A program.
* ``faithful=False`` — beyond-paper: FSDP parameter/optimizer sharding
  (reduce-scatter + all-gather), sequence parallelism, donation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import registry
from repro.models.common import ShardRules
from repro.optim import OptConfig, apply_update, init_state, state_pspecs


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    num_slices: int = 1          # paper §5.1 automated input slicing
    remat: Any = True            # False | True | "dots" (see common.remat_wrap)
    faithful: bool = False       # paper-faithful replicated-DP mode
    accum_dtype: str = "float32" # microbatch gradient accumulator dtype


def _split_batch(batch: dict, k: int) -> dict:
    def sp(x):
        b = x.shape[0]
        if b % k:
            raise ValueError(f"num_slices={k} must divide global batch {b}")
        return x.reshape((k, b // k) + x.shape[1:])

    return {n: sp(v) for n, v in batch.items()}


def build_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardRules,
    opt: OptConfig,
    settings: TrainSettings = TrainSettings(),
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    mod = registry.get_module(cfg)

    def loss_for_grad(params, microbatch):
        loss, metrics = mod.loss_fn(
            cfg, mesh, rules, params, microbatch, remat=settings.remat
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def compute_grads(params, batch):
        k = settings.num_slices
        if k == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        slices = _split_batch(batch, k)
        adt = jnp.dtype(settings.accum_dtype)

        def body(carry, mb):
            loss_acc, m_acc, g_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(adt) / k, g_acc, grads
            )
            m_acc = jax.tree.map(lambda a, m: a + m / k, m_acc, metrics)
            return (loss_acc + loss / k, m_acc, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        probe = jax.eval_shape(
            lambda p, b: grad_fn(p, b)[0][1], params,
            jax.tree.map(lambda x: x[0], slices),
        )
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), probe)
        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.float32(0.0), m0, g0), slices
        )
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, opt_metrics = apply_update(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


# ---------------------------------------------------------------------------
# Jitted assembly with shardings (the object the dry-run lowers)
# ---------------------------------------------------------------------------


def shardings_for(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def jit_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: ShardRules,
    opt: OptConfig,
    shape: ShapeConfig,
    settings: TrainSettings = TrainSettings(),
    *,
    donate: bool = True,
):
    """Returns (jitted fn, (params_sds, opt_sds, batch_sds), in_shardings)."""
    step = build_train_step(cfg, mesh, rules, opt, settings)

    params_sds = registry.abstract_params(cfg)
    p_pspecs = registry.param_pspecs(cfg, rules)
    opt_sds = jax.eval_shape(partial(init_state, opt), params_sds)
    o_pspecs = state_pspecs(opt, p_pspecs)
    batch_sds, b_pspecs = registry.train_inputs(cfg, shape, rules)

    in_sh = (
        shardings_for(mesh, p_pspecs),
        shardings_for(mesh, o_pspecs),
        shardings_for(mesh, b_pspecs),
    )
    out_sh = (in_sh[0], in_sh[1], None)
    jitted = jax.jit(
        step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (params_sds, opt_sds, batch_sds), in_sh
