"""Minimal deterministic stand-in for the `hypothesis` package.

The container image may not ship `hypothesis`; rather than skip the
property tests, conftest installs this module under the name
``hypothesis`` when the real package is missing.  It covers exactly the
surface the test suite uses — ``@given`` with keyword strategies,
``@settings(max_examples=, deadline=)``, and the ``sampled_from`` /
``integers`` / ``lists`` strategies — drawing a fixed number of examples
from a per-test seeded RNG so runs are reproducible.  No shrinking, no
database, no health checks.
"""
from __future__ import annotations

import inspect
import random

# marker for the wiring test: distinguishes this stand-in from the real
# package after conftest aliases it into sys.modules["hypothesis"]
IS_MINI = True


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(
        lambda rng: [
            elements._draw(rng) for _ in range(rng.randint(min_size, max_size))
        ]
    )


class strategies:
    sampled_from = staticmethod(sampled_from)
    integers = staticmethod(integers)
    lists = staticmethod(lists)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._mini_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_max_examples", None) or getattr(
                fn, "_mini_max_examples", 10
            )
            rng = random.Random(f"minihypothesis::{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s._draw(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # Copy identity WITHOUT functools.wraps: __wrapped__ would make
        # pytest introspect the original signature and treat the strategy
        # parameters as fixtures.  Any non-strategy params of fn stay
        # visible so real fixtures still work.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        remaining = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strats
        ]
        wrapper.__signature__ = inspect.Signature(remaining)
        return wrapper

    return deco
