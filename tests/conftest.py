"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 real CPU device;
multi-device semantics tests spawn subprocesses with
``--xla_force_host_platform_device_count`` (see tests/md/)."""
import sys

import jax
import pytest

# Prefer the REAL hypothesis whenever the image ships it; only fall back
# to the deterministic mini stand-in when the import fails.  The property
# tests use only the surface both implement (given/settings/strategies),
# so the same tests get shrinking + health checks for free once the
# package lands.  tests/test_engine_fuzz.py::test_hypothesis_selection
# asserts the selection matches what's installed.
try:
    import hypothesis  # noqa: F401
except ImportError:  # image without hypothesis: install the mini stand-in
    import _minihypothesis

    sys.modules["hypothesis"] = _minihypothesis

from repro.launch.mesh import single_device_mesh
from repro.models.common import ShardRules


@pytest.fixture(scope="session")
def mesh():
    return single_device_mesh()


@pytest.fixture(scope="session")
def rules(mesh):
    return ShardRules.for_mesh(mesh)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
