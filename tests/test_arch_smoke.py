"""Per-architecture smoke tests (deliverable f): reduced same-family
configs run one forward/train step on CPU, asserting shapes + finiteness.
The FULL configs are exercised via the dry-run only."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.optim import OptConfig
from repro.train.step import TrainSettings, build_train_step
from repro.optim import init_state


def _batch(cfg, B, S, key):
    s_text = S - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    b = {"tokens": jax.random.randint(key, (B, s_text + 1), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, mesh, rules, key):
    cfg = get_smoke_config(arch)
    mod = registry.get_module(cfg)
    params = mod.init(cfg, key)
    batch = _batch(cfg, 2, 32, key)

    loss, metrics = jax.jit(
        lambda p, b: mod.loss_fn(cfg, mesh, rules, p, b)
    )(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert np.isfinite(float(metrics["ce_loss"]))

    # one full train step (grads + adam update): params change, stay finite
    opt = OptConfig(kind="adam", lr=1e-3)
    step = build_train_step(cfg, mesh, rules, opt, TrainSettings(num_slices=2))
    opt_state = init_state(opt, params)
    new_params, new_opt, m2 = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))
    changed = jax.tree.map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
        params, new_params)
    assert any(jax.tree.leaves(changed)), f"{arch}: update did not change params"
    for leaf in jax.tree.leaves(new_params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Pin the exact published configs (guards accidental edits)."""
    cfg = get_config(arch)
    expect = {
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102_400),
        "smollm-360m": (32, 960, 15, 5, 2560, 49_152),
        "stablelm-12b": (40, 5120, 32, 8, 13_824, 100_352),
        "gemma2-27b": (46, 4608, 32, 16, 36_864, 256_000),
        "internvl2-76b": (80, 8192, 64, 8, 28_672, 128_256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32_000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51_865),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50_304),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151_936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151_936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab)
    assert got == expect, f"{arch}: {got} != {expect}"
    if arch.startswith("qwen3-moe"):
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8
        assert cfg.moe.d_expert == (768 if "30b" in arch else 1536)
    if arch == "zamba2-1.2b":
        assert cfg.ssm.state == 64 and cfg.subquadratic
    if arch == "gemma2-27b":
        assert cfg.alt_local_global and cfg.attn_softcap == 50.0 \
            and cfg.logit_softcap == 30.0
    if arch == "xlstm-1.3b":
        assert cfg.subquadratic
