"""Chunked attention vs naive oracle; distributed decode correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    DecodeSharding, chunked_attention, decode_attention, pick_chunk,
    reference_attention, rope,
)


def _mk(B, S, H, Hk, D, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(dtype))
    k = jnp.asarray(rng.normal(size=(B, S, Hk, D)).astype(dtype))
    v = jnp.asarray(rng.normal(size=(B, S, Hk, D)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=24),
    dict(causal=True, window=16),
    dict(causal=True, softcap=20.0),
    dict(causal=True, window=16, softcap=30.0),
    dict(causal=True, kv_len=40),
])
def test_chunked_matches_reference(kwargs):
    q, k, v = _mk(2, 64, 8, 2, 16)
    out = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16, **kwargs)
    ref = reference_attention(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@settings(max_examples=20, deadline=None)
@given(
    S=st.sampled_from([32, 48, 64]),
    chunk=st.sampled_from([8, 16, 64]),
    rep=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([0, 8, 24]),
)
def test_chunked_property_sweep(S, chunk, rep, window):
    Hk = 2
    q, k, v = _mk(1, S, Hk * rep, Hk, 8, seed=S + chunk)
    out = chunked_attention(q, k, v, q_chunk=chunk, kv_chunk=chunk,
                            causal=True, window=window)
    ref = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=5e-5)


def test_pick_chunk():
    assert pick_chunk(1500, 256) == 250
    assert pick_chunk(4096, 256) == 256
    assert pick_chunk(7, 256) == 7
    assert pick_chunk(13, 4) == 1


def test_rope_preserves_norm_and_relativity():
    B, S, H, D = 2, 16, 2, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    xr = rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(xr), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jnp.asarray(rng.normal(size=(1, 1, 1, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, D)).astype(np.float32))
    def dot(m, n):
        qm = rope(q, jnp.full((1, 1), m))
        kn = rope(k, jnp.full((1, 1), n))
        return float(jnp.sum(qm * kn))
    np.testing.assert_allclose(dot(3, 1), dot(7, 5), rtol=1e-4)


@pytest.mark.parametrize("window", [0, 6])
def test_decode_matches_reference_chain(mesh, window):
    """Run 6 decode steps; each must match the naive attention over the
    prefix (the distributed flash-decode LSE combine is exact)."""
    B, Hk, rep, D, Smax = 2, 2, 3, 8, 16
    H = Hk * rep
    rng = np.random.default_rng(1)
    ks = jnp.asarray(rng.normal(size=(B, Smax, Hk, D)).astype(np.float32))
    vs = jnp.asarray(rng.normal(size=(B, Smax, Hk, D)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(B, Smax, H, D)).astype(np.float32))
    sh = DecodeSharding.choose(mesh, B)
    kc = jnp.zeros((B, Smax, Hk, D), jnp.float32)
    vc = jnp.zeros_like(kc)
    for t in range(6):
        q = qs[:, t].reshape(B, Hk, rep, D)
        out, kc, vc = decode_attention(
            q, kc, vc, ks[:, t], vs[:, t], jnp.int32(t),
            sharding=sh, window=window,
        )
        ref = reference_attention(
            qs[:, t:t + 1], ks[:, :t + 1], vs[:, :t + 1],
            causal=True, window=window, q_offset=t,
        )
        np.testing.assert_allclose(
            np.asarray(out).reshape(B, 1, H, D), np.asarray(ref),
            atol=3e-5, rtol=3e-5)
