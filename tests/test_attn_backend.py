"""The Pallas attention backend is a drop-in for the XLA chunked path:
the full model loss must agree between attn_impl='chunked' and 'pallas'
(kernel runs in interpret mode on CPU)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import registry


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma2-27b"])
def test_pallas_backend_matches_chunked(arch, mesh, rules, key):
    base = dataclasses.replace(get_smoke_config(arch), compute_dtype="float32")
    mod = registry.get_module(base)
    params = mod.init(base, key)
    batch = {"tokens": jax.random.randint(key, (2, 33), 0, base.vocab)}

    losses = {}
    for impl in ("chunked", "pallas"):
        cfg = dataclasses.replace(base, attn_impl=impl)
        loss, _ = jax.jit(
            lambda p, b, c=cfg: registry.get_module(c).loss_fn(c, mesh, rules, p, b)
        )(params, batch)
        losses[impl] = float(loss)
    np.testing.assert_allclose(losses["pallas"], losses["chunked"],
                               rtol=2e-5, atol=2e-5)


def test_pallas_backend_trainable(mesh, rules, key):
    """The custom VJP makes the kernel path differentiable end to end."""
    cfg = dataclasses.replace(get_smoke_config("smollm-360m"),
                              compute_dtype="float32", attn_impl="pallas")
    mod = registry.get_module(cfg)
    params = mod.init(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 17), 0, cfg.vocab)}
    grads = jax.jit(jax.grad(
        lambda p, b: mod.loss_fn(cfg, mesh, rules, p, b)[0]
    ))(params, batch)
    gn = sum(float(jax.numpy.sum(g.astype(jax.numpy.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0
