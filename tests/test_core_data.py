"""SynkData host objects (paper §4.1) + slicing machinery on one device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as synk
from repro.core.slicing import sliced_call
from repro.core.specs import Reduce


def test_synkdata_overallocation_growth():
    x = np.arange(12.0, dtype=np.float32).reshape(6, 2)
    d = synk.data(x, oversize=2.0)
    assert d.capacity >= 12 // 2
    assert d.shape == (6, 2)
    buf_before = d._buffer
    d.set_length(9)                   # grow within capacity: no realloc
    assert d._buffer is buf_before
    assert d.shape == (9, 2)
    d.set_length(4)                   # shrink: view only
    np.testing.assert_array_equal(d.array, x[:4])
    d.set_length(d.capacity + 5)      # beyond capacity: realloc, data kept
    np.testing.assert_array_equal(d.array[:4], x[:4])
    d.free()
    assert len(d) == 0


def test_synkdata_numpy_interface():
    x = np.arange(10.0, dtype=np.float32)
    d = synk.data(x)
    d[3] = 99.0
    assert d[3] == 99.0
    assert np.asarray(d).shape == (10,)
    np.testing.assert_array_equal(d.excerpt([1, 3]), np.array([1.0, 99.0]))


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([1, 2, 4, 8]),
    op=st.sampled_from(["mean", "sum", "max", "min"]),
)
def test_slicing_aggregation_equivalence(b, k, op):
    """Paper §5.1 invariant: slicing must not change results."""
    rng = np.random.default_rng(b * 100 + k)
    x = jnp.asarray(rng.normal(size=(b, 4)).astype(np.float32))

    fn = {
        "mean": lambda x: jnp.mean(x),
        "sum": lambda x: jnp.sum(x),
        "max": lambda x: jnp.max(x),
        "min": lambda x: jnp.min(x),
    }[op]
    direct = fn(x)
    sliced = sliced_call(fn, [x], [True], Reduce(op), k)
    np.testing.assert_allclose(np.asarray(sliced), np.asarray(direct),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([8, 24]), k=st.sampled_from([2, 4]))
def test_slicing_concat_and_last(b, k):
    rng = np.random.default_rng(b + k)
    x = jnp.asarray(rng.normal(size=(b, 3)).astype(np.float32))
    out = sliced_call(lambda x: x * 2.0, [x], [True], Reduce("concat"), k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2, rtol=1e-6)
    last = sliced_call(lambda x: jnp.sum(x, 0), [x], [True], Reduce("last"), k)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(x[-(b // k):].sum(0)), rtol=1e-5)


def test_slicing_indivisible_raises():
    x = jnp.ones((10, 2))
    with pytest.raises(ValueError, match="num_slices"):
        sliced_call(lambda x: jnp.mean(x), [x], [True], Reduce("mean"), 3)


def test_slicing_broadcast_args_use_original_values():
    """Paper: 'all slices are computed using the original values'."""
    x = jnp.arange(8.0).reshape(8, 1)
    w = jnp.float32(3.0)
    out = sliced_call(lambda x, w: jnp.sum(x) * w, [x, w], [True, False],
                      Reduce("sum"), 4)
    np.testing.assert_allclose(float(out), float(jnp.sum(x) * 3.0), rtol=1e-6)
