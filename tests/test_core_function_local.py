"""Local semantics of synk.function (fast paths + regressions).

Written against however many local devices exist (1 in the default
pytest run; scripts/ci.sh re-runs the suite under 8 forced host devices),
so sizes scale with ``ctx.n_data``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as synk


@pytest.fixture(autouse=True)
def fresh_ctx():
    synk.reset()
    yield
    synk.reset()


def test_pytree_arguments():
    """Regression: args may be parameter pytrees (paper Appendix A passes
    the network params dict)."""
    synk.fork()

    def step(x, params):
        return jnp.mean(x @ params["w"] + params["b"])

    f = synk.function(step, [synk.Scatter(), synk.Broadcast()],
                      synk.Reduce("mean"))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 4)).astype(np.float32)
    params = {"w": rng.normal(size=(4, 2)).astype(np.float32),
              "b": np.float32(0.5)}
    got = f(x, params)
    want = np.mean(x @ params["w"] + params["b"])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_pytree_outputs_prefix_spec():
    ctx = synk.fork()
    n = ctx.n_data

    def step(x, params):
        new = jax.tree.map(lambda p: p + 1.0, params)
        return jnp.sum(x), new

    f = synk.function(step, [synk.Scatter(), synk.Broadcast()],
                      (synk.Reduce("sum"), synk.Reduce(None)))
    x = np.ones((8 * n, 2), np.float32)
    params = {"w": np.zeros(3, np.float32), "b": np.float32(1.0)}
    s, new = f(x, params)
    np.testing.assert_allclose(s, 16.0 * n)
    np.testing.assert_allclose(np.asarray(new["w"]), np.ones((n, 3)))


def test_wrong_arity_raises():
    synk.fork()
    f = synk.function(lambda x: x, [synk.Scatter()], synk.Reduce("mean"))
    with pytest.raises(TypeError, match="takes 1 inputs"):
        f(np.ones(4), np.ones(4))


def test_indivisible_scatter():
    ctx = synk.fork()
    f = synk.function(lambda x: jnp.mean(x), [synk.Scatter()], synk.Reduce("mean"))
    if ctx.n_data == 1:  # 1 device: everything divides
        out = f(np.ones((3, 2), np.float32))
        np.testing.assert_allclose(out, 1.0)
    else:
        with pytest.raises(ValueError, match="divide"):
            f(np.ones((ctx.n_data + 1, 2), np.float32))


def test_bad_specs_raise():
    with pytest.raises(ValueError):
        synk.function(lambda x: x, ["bogus"], synk.Reduce("mean"))
    with pytest.raises(ValueError):
        synk.Reduce("median")
    with pytest.raises(NotImplementedError):
        synk.Scatter(axis=1)


def test_call_caching():
    ctx = synk.fork()
    n = ctx.n_data
    calls = []

    def fn(x):
        calls.append(1)       # traced once per signature
        return jnp.sum(x)

    f = synk.function(fn, [synk.Scatter()], synk.Reduce("sum"))
    f(np.ones((4 * n, 2), np.float32))
    f(np.full((4 * n, 2), 2.0, np.float32))      # same shapes: cached
    n_after_same = len(calls)
    assert f.stats["builds"] == 1 and f.stats["calls"] == 2
    f(np.ones((8 * n, 2), np.float32))           # new shape: retrace
    assert len(calls) > n_after_same
    assert f.stats["builds"] == 2


def test_device_put_skipped_for_resident_arrays():
    ctx = synk.fork()
    f = synk.function(lambda x: jnp.sum(x), [synk.Scatter()], synk.Reduce("sum"))
    x = np.ones((4 * ctx.n_data, 2), np.float32)
    f(x)
    xs = jax.device_put(x, ctx.sharding(ctx.data_spec(None)))
    before = f.stats["device_put_skips"]
    np.testing.assert_allclose(f(xs), x.sum())
    assert f.stats["device_put_skips"] == before + 1


def test_donate_scattered_inputs():
    ctx = synk.fork()
    f = synk.function(lambda x: jnp.sum(x), [synk.Scatter()],
                      synk.Reduce("sum"), donate=True)
    x = np.ones((4 * ctx.n_data, 2), np.float32)
    np.testing.assert_allclose(f(x), x.sum())
    np.testing.assert_allclose(f(x + 1), (x + 1).sum())  # fresh staging each call
