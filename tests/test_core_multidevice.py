"""Multi-device semantics of the Synkhronos core, via subprocesses with 8
forced host devices (this process keeps 1 device)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_check(name: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, "-m", "repro.testing.md_checks", name],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr[-3000:]}"


def test_scatter_reduce():
    run_check("scatter_reduce")


def test_indexing():
    run_check("indexing")


def test_collectives():
    run_check("collectives")


def test_sgd_parity_with_serial_program():
    """Paper Appendix A: the multi-GPU SGD program must match serial SGD."""
    run_check("sgd_parity")


def test_elastic_restore():
    """Checkpoint from a dp=8 mesh restores and trains on a dp=4xtp=2 mesh."""
    run_check("elastic")


def test_global_indexing():
    """Regression: device-resident batch= ids are GLOBAL rows; shuffled
    indices crossing shard boundaries must read the right rows, concat
    outputs slice back to the request length (incl. pad > len(idx))."""
    run_check("indexing_global")


def test_bucketed_reduce_matches_monolithic():
    """Bucketed flat all-reduce == monolithic pmean bit-for-bit (fp32)."""
    run_check("bucketed_reduce")


def test_flat_engine_parity():
    """Faithful flat engine and ZeRO flat engine track the legacy GSPMD
    adam step loss-for-loss over several steps on dp=8."""
    run_check("flat_parity")
