"""Strong serving correctness: prefill logits == forward logits at the
last position, and the first decode step == forward at the next position.
Run in f32 so the comparison is tight."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import registry


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch, mesh, rules, key):
    cfg = dataclasses.replace(
        get_smoke_config(arch), compute_dtype="float32")
    if cfg.moe.num_experts:
        # no-drop capacity: GShard drops depend on how many tokens share the
        # batch, so prefill-vs-decode would legitimately diverge otherwise
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    mod = registry.get_module(cfg)
    params = mod.init(cfg, key)
    B, S = 2, 24
    s_text = S - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    tokens = jax.random.randint(key, (B, s_text + 1), 0, cfg.vocab)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        extra = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))

    # teacher-forced loss over tokens[:, :S+1] gives logits via loss path;
    # instead compare prefill(t[:, :n]) vs prefill(t[:, :n+1]).
    n = s_text - 1
    cache, logits_a = jax.jit(
        lambda p, t, e: mod.prefill(cfg, mesh, rules, p, t, e,
                                    max_len=s_text + 8)
    )(params, tokens[:, :n], extra)
    _, logits_b = jax.jit(
        lambda p, t, e: mod.prefill(cfg, mesh, rules, p, t, e,
                                    max_len=s_text + 8)
    )(params, tokens[:, :n + 1], extra)

    seq = n + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    logits_d, _ = jax.jit(
        lambda p, c, t: mod.decode_step(cfg, mesh, rules, p, c, t,
                                        jnp.int32(seq))
    )(params, cache, tokens[:, n].astype(jnp.int32))

    # decoding token n (with cache of the first n) == prefill over n+1 tokens
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_b),
        atol=2e-3, rtol=2e-3,
    ), arch
