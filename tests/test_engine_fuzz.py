"""Cross-engine randomized parity fuzzer.

THE correctness property of the whole serve subsystem, in the paper's
terms: every memory-management strategy the engine layers on — paged
block tables, chunked prefill, refcounted prefix caching (COW tails,
decode-boundary publication), preempt-and-requeue admission — must be
*behavior-invisible*: token-for-token identical to the simple slotted
engine under greedy decoding, on arbitrary request streams.

Each seeded episode draws a random request stream (bursty arrivals,
shared and disjoint prompt prefixes, mixed lengths and budgets, natural
mid-stream evictions as budgets expire) and replays it through every
engine mode — paged, chunked, prefix-cached, preempting, and their
combinations; after every step the paged engines run the full allocator
invariant sweep (refcount conservation, free + live + cached == pool,
compaction, no KV position outside its lane's mapped blocks).

Episode count: ``ENGINE_FUZZ_EPISODES`` env var (default below);
``scripts/ci.sh`` runs the 200-episode sweep.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.models import registry
from repro.serve import EngineConfig, ServeEngine

EPISODES = int(os.environ.get("ENGINE_FUZZ_EPISODES", "200"))
MAX_SLOTS, MAX_LEN, BS = 3, 48, 8

# The engine modes under test; "slotted" is the parity reference.  The
# preempting pools sit far below the lanes' combined worst case (capacity
# 5 blocks vs 3 lanes x up to 4), so decode growth preempts routinely —
# once with re-prefill-everything resumes, once with the resume riding
# its own published prefix chain.
MODES = {
    "slotted": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
    "paged": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS),
    "paged_chunked": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, prefill_chunk=BS),
    "prefix": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, prefix_cache=True),
    "prefix_chunked": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, prefill_chunk=BS, prefix_cache=True),
    "preempt": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, admission="preempt"),
    "prefix_preempt": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, prefix_cache=True,
        admission="preempt"),
    # everything at once: chunked prefill whose chunks can preempt
    # mid-prompt, prefix hits at chunk offsets, restores amid chunking
    "preempt_chunked": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, prefill_chunk=BS, prefix_cache=True,
        admission="preempt"),
}


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    # f32 so greedy streams are exactly comparable across engines
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    # ONE AotCache across every episode and mode: per-mode executables
    # compile once, then 200 episodes dispatch from cache
    return cfg, mesh, rules, params, AotCache("fuzz")


def make_stream(rng, vocab):
    """Random request stream: (arrival_tick, prompt, budget) triples.

    Prompts mix block-aligned shared prefixes (system prompts — including
    exact-multiple lengths that exercise the COW tail), shared prefixes
    with unique tails, and fully disjoint prompts; bursty arrivals admit
    several requests into one step and quiet gaps drain lanes mid-stream.
    """
    n_prefix = int(rng.integers(1, 3))
    prefixes = [
        rng.integers(0, vocab, int(rng.integers(1, 3)) * BS).astype(np.int32)
        for _ in range(n_prefix)
    ]
    out, tick = [], 0
    for _ in range(int(rng.integers(3, 9))):
        tick += int(rng.integers(0, 4))         # 0 => same-step burst
        r = rng.random()
        if r < 0.25:                            # whole shared prefix (COW)
            prompt = prefixes[int(rng.integers(n_prefix))].copy()
        elif r < 0.7:                           # shared prefix + unique tail
            pre = prefixes[int(rng.integers(n_prefix))]
            tail = rng.integers(0, vocab, int(rng.integers(1, 9)))
            prompt = np.concatenate([pre, tail.astype(np.int32)])
        else:                                   # disjoint prompt
            prompt = rng.integers(
                0, vocab, int(rng.integers(1, 25))).astype(np.int32)
        budget = int(rng.integers(1, 9))
        # keep every request within max_len and the preempt pool's worst case
        prompt = prompt[: MAX_LEN - budget - BS]
        out.append((tick, prompt, budget))
    return out


def drive(cfg, mesh, rules, params, aot, ec, stream):
    """Replay a stream through one engine; invariants swept every step."""
    eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
    i, tick, guard = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        eng.step()
        eng.check_invariants()
        tick += 1
        guard += 1
        assert guard < 2000, "engine failed to drain (livelock?)"
    return [list(eng.completions[r].tokens) for r in range(len(stream))], eng


def test_fuzz_cross_engine_parity(setup):
    cfg, mesh, rules, params, aot = setup
    totals = {name: 0 for name in MODES}
    exercised = {"preemptions": 0, "prefix_hit_tokens": 0, "cow_copies": 0,
                 "prefill_chunks": 0}
    for seed in range(EPISODES):
        rng = np.random.default_rng(1000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
        for name, ec in MODES.items():
            if name == "slotted":
                continue
            got, eng = drive(cfg, mesh, rules, params, aot, ec, stream)
            assert got == want, (
                f"episode seed={seed}: engine {name!r} diverged from "
                f"slotted greedy output\n  want={want}\n  got ={got}")
            totals[name] += 1
            # every block back home once drained (cached blocks are legal)
            assert eng.alloc.in_use == 0
            assert eng.alloc.num_free + eng.alloc.num_cached \
                == eng.alloc.capacity
            for k in exercised:
                exercised[k] += eng.counters.get(k, 0)
    # the stream generator must actually exercise the machinery under
    # test, otherwise parity is vacuous (skipped for tiny debug sweeps
    # where a given feature may legitimately never trigger)
    assert exercised["prefill_chunks"] > 0
    if EPISODES >= 20:
        assert exercised["prefix_hit_tokens"] > 0, "no prefix hits at all"
        assert exercised["cow_copies"] > 0, "no COW tails in any episode"
        assert exercised["preemptions"] > 0, "no preemptions in any episode"


# ---------------------------------------------------------------------------
# Recurrent state kinds (engine modes 9 + 10): ssm (xLSTM) + hybrid (Zamba)
# ---------------------------------------------------------------------------
#
# The recurrent families serve on the slotted layout only (no block pool,
# so no pool-pressure preemption) — the adversarial schedule here is
# HOST-INITIATED preemption (`ServeEngine.preempt`), the hook an external
# priority scheduler would use.  The parity reference is the same engine
# without preemptions: resume re-prefills the prompt through the SAME
# bucket executable and replays decode, so parity is bitwise by
# construction — any divergence is a real requeue/replay/zeroing bug.
# Runs a slice of the main episode budget (two extra families per episode).

REC_EPISODES = max(2, EPISODES // 10)
REC_ARCHS = ("xlstm-1.3b", "zamba2-1.2b")


@pytest.fixture(scope="module", params=REC_ARCHS)
def rec_setup(request):
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config(request.param), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params, AotCache(f"fuzz-{cfg.family}")


def drive_recurrent(cfg, mesh, rules, params, aot, stream, preempts):
    """Replay a stream through a slotted recurrent engine; ``preempts``
    maps tick -> slot to preempt (empty = the parity reference).  Sweeps
    the allocator-free invariants plus recurrent evict-time zeroing."""
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN), aot=aot)
    i, tick, guard = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        eng.step()
        eng.check_invariants()
        slot = preempts.get(tick)
        if slot is not None and eng.slots[slot] is not None:
            eng.preempt(slot)
        tick += 1
        guard += 1
        assert guard < 2000, "engine failed to drain (livelock?)"
    # drained: every lane free and (checked inside, post-decode) every
    # recurrent leaf exactly zero
    assert all(s is None for s in eng.slots)
    eng.check_invariants()
    return [list(eng.completions[r].tokens) for r in range(len(stream))], eng


def test_fuzz_recurrent_preempt_parity(rec_setup):
    cfg, mesh, rules, params, aot = rec_setup
    preempted = replayed = 0
    for seed in range(REC_EPISODES):
        rng = np.random.default_rng(5000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive_recurrent(
            cfg, mesh, rules, params, aot, stream, {})
        preempts = {
            int(t): int(rng.integers(MAX_SLOTS))
            for t in rng.integers(1, 30, size=int(rng.integers(1, 4)))
        }
        got, eng = drive_recurrent(
            cfg, mesh, rules, params, aot, stream, preempts)
        assert got == want, (
            f"episode seed={seed}: preempted {cfg.family} engine diverged"
            f"\n  want={want}\n  got ={got}")
        preempted += eng.counters["preemptions"]
        replayed += eng.counters["replayed_tokens"]
    # the schedule must actually exercise preempt-and-requeue
    if REC_EPISODES >= 5:
        assert preempted > 0, "no recurrent preemption in any episode"
        assert replayed > 0, "no decode replay in any episode"


def test_fuzz_episode_determinism(setup):
    """The same seed replays to the same stream and the same tokens —
    fuzz failures are reproducible by seed number."""
    cfg, mesh, rules, params, aot = setup
    s1 = make_stream(np.random.default_rng(1000), cfg.vocab)
    s2 = make_stream(np.random.default_rng(1000), cfg.vocab)
    assert len(s1) == len(s2)
    assert all(
        a[0] == b[0] and np.array_equal(a[1], b[1]) and a[2] == b[2]
        for a, b in zip(s1, s2)
    )
    a, _ = drive(cfg, mesh, rules, params, aot, MODES["preempt"], s1)
    b, _ = drive(cfg, mesh, rules, params, aot, MODES["preempt"], s2)
    assert a == b


def test_hypothesis_selection():
    """conftest must install the real ``hypothesis`` when the image ships
    it and the ``_minihypothesis`` stand-in only as a fallback."""
    import importlib.metadata

    import hypothesis

    try:
        importlib.metadata.version("hypothesis")
        real_available = True
    except importlib.metadata.PackageNotFoundError:
        real_available = False
    if real_available:
        assert not getattr(hypothesis, "IS_MINI", False)
        assert hypothesis.__name__ == "hypothesis"
    else:
        assert getattr(hypothesis, "IS_MINI", False)
