"""Cross-engine randomized parity fuzzer.

THE correctness property of the whole serve subsystem, in the paper's
terms: every memory-management strategy the engine layers on — paged
block tables, chunked prefill, refcounted prefix caching (COW tails,
decode-boundary publication), preempt-and-requeue admission — must be
*behavior-invisible*: token-for-token identical to the simple slotted
engine under greedy decoding, on arbitrary request streams.

Each seeded episode draws a random request stream (bursty arrivals,
shared and disjoint prompt prefixes, mixed lengths and budgets, natural
mid-stream evictions as budgets expire) and replays it through every
engine mode — paged, chunked, prefix-cached, preempting, and their
combinations; after every step the paged engines run the full allocator
invariant sweep (refcount conservation, free + live + cached == pool,
compaction, no KV position outside its lane's mapped blocks).

Episode count: ``ENGINE_FUZZ_EPISODES`` env var (default below);
``scripts/ci.sh`` runs the 200-episode sweep.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.models import registry
from repro.serve import EngineConfig, ServeEngine

EPISODES = int(os.environ.get("ENGINE_FUZZ_EPISODES", "200"))
MAX_SLOTS, MAX_LEN, BS = 3, 48, 8

# The engine modes under test; "slotted" is the parity reference.  The
# preempting pools sit far below the lanes' combined worst case (capacity
# 5 blocks vs 3 lanes x up to 4), so decode growth preempts routinely —
# once with re-prefill-everything resumes, once with the resume riding
# its own published prefix chain.
MODES = {
    "slotted": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
    "paged": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS),
    "paged_chunked": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, prefill_chunk=BS),
    "prefix": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, prefix_cache=True),
    "prefix_chunked": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, prefill_chunk=BS, prefix_cache=True),
    "preempt": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, admission="preempt"),
    "prefix_preempt": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, prefix_cache=True,
        admission="preempt"),
    # everything at once: chunked prefill whose chunks can preempt
    # mid-prompt, prefix hits at chunk offsets, restores amid chunking
    "preempt_chunked": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, prefill_chunk=BS, prefix_cache=True,
        admission="preempt"),
    # host-RAM tier: preempted lanes spill and resume O(copy) instead of
    # replaying — THE gate for the tier being behavior-invisible
    "tiered": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, admission="preempt", host_tier=True),
    # tier + prefix cache + chunking: LRU-evicted chains spill to host
    # and promote back on later matches, amid lane spills and chunked
    # prefills racing the same pool
    "tiered_prefix": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, prefill_chunk=BS, prefix_cache=True,
        admission="preempt", host_tier=True),
}


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    # f32 so greedy streams are exactly comparable across engines
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    # ONE AotCache across every episode and mode: per-mode executables
    # compile once, then 200 episodes dispatch from cache
    return cfg, mesh, rules, params, AotCache("fuzz")


def make_stream(rng, vocab):
    """Random request stream: (arrival_tick, prompt, budget) triples.

    Prompts mix block-aligned shared prefixes (system prompts — including
    exact-multiple lengths that exercise the COW tail), shared prefixes
    with unique tails, and fully disjoint prompts; bursty arrivals admit
    several requests into one step and quiet gaps drain lanes mid-stream.
    """
    n_prefix = int(rng.integers(1, 3))
    prefixes = [
        rng.integers(0, vocab, int(rng.integers(1, 3)) * BS).astype(np.int32)
        for _ in range(n_prefix)
    ]
    out, tick = [], 0
    for _ in range(int(rng.integers(3, 9))):
        tick += int(rng.integers(0, 4))         # 0 => same-step burst
        r = rng.random()
        if r < 0.25:                            # whole shared prefix (COW)
            prompt = prefixes[int(rng.integers(n_prefix))].copy()
        elif r < 0.7:                           # shared prefix + unique tail
            pre = prefixes[int(rng.integers(n_prefix))]
            tail = rng.integers(0, vocab, int(rng.integers(1, 9)))
            prompt = np.concatenate([pre, tail.astype(np.int32)])
        else:                                   # disjoint prompt
            prompt = rng.integers(
                0, vocab, int(rng.integers(1, 25))).astype(np.int32)
        budget = int(rng.integers(1, 9))
        # keep every request within max_len and the preempt pool's worst case
        prompt = prompt[: MAX_LEN - budget - BS]
        out.append((tick, prompt, budget))
    return out


def drive(cfg, mesh, rules, params, aot, ec, stream, draft_params=None):
    """Replay a stream through one engine; invariants swept every step."""
    eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot,
                      draft_params=draft_params)
    i, tick, guard = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        eng.step()
        eng.check_invariants()
        tick += 1
        guard += 1
        assert guard < 2000, "engine failed to drain (livelock?)"
    return [list(eng.completions[r].tokens) for r in range(len(stream))], eng


def test_fuzz_cross_engine_parity(setup):
    cfg, mesh, rules, params, aot = setup
    totals = {name: 0 for name in MODES}
    exercised = {"preemptions": 0, "prefix_hit_tokens": 0, "cow_copies": 0,
                 "prefill_chunks": 0, "spills": 0, "restores": 0}
    for seed in range(EPISODES):
        rng = np.random.default_rng(1000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
        for name, ec in MODES.items():
            if name == "slotted":
                continue
            got, eng = drive(cfg, mesh, rules, params, aot, ec, stream)
            assert got == want, (
                f"episode seed={seed}: engine {name!r} diverged from "
                f"slotted greedy output\n  want={want}\n  got ={got}")
            totals[name] += 1
            # every block back home once drained (cached blocks are legal)
            assert eng.alloc.in_use == 0
            assert eng.alloc.num_free + eng.alloc.num_cached \
                == eng.alloc.capacity
            if eng.tier is not None:
                # every lane spill consumed or dropped with its request;
                # host-resident prefix blocks are legal (like cached)
                eng.tier.check()
                assert eng.tier.spilled_lanes == 0
            for k in exercised:
                exercised[k] += eng.counters.get(k, 0)
    # the stream generator must actually exercise the machinery under
    # test, otherwise parity is vacuous (skipped for tiny debug sweeps
    # where a given feature may legitimately never trigger)
    assert exercised["prefill_chunks"] > 0
    if EPISODES >= 20:
        assert exercised["prefix_hit_tokens"] > 0, "no prefix hits at all"
        assert exercised["cow_copies"] > 0, "no COW tails in any episode"
        assert exercised["preemptions"] > 0, "no preemptions in any episode"
        assert exercised["spills"] > 0, "no lane ever spilled to the tier"
        assert exercised["restores"] > 0, "no lane ever restored O(copy)"


# ---------------------------------------------------------------------------
# Recurrent state kinds (engine modes 9 + 10): ssm (xLSTM) + hybrid (Zamba)
# ---------------------------------------------------------------------------
#
# The recurrent families serve on the slotted layout only (no block pool,
# so no pool-pressure preemption) — the adversarial schedule here is
# HOST-INITIATED preemption (`ServeEngine.preempt`), the hook an external
# priority scheduler would use.  The parity reference is the same engine
# without preemptions: resume re-prefills the prompt through the SAME
# bucket executable and replays decode, so parity is bitwise by
# construction — any divergence is a real requeue/replay/zeroing bug.
# Runs a slice of the main episode budget (two extra families per episode).

REC_EPISODES = max(2, EPISODES // 10)
REC_ARCHS = ("xlstm-1.3b", "zamba2-1.2b")


@pytest.fixture(scope="module", params=REC_ARCHS)
def rec_setup(request):
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config(request.param), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params, AotCache(f"fuzz-{cfg.family}")


def drive_recurrent(cfg, mesh, rules, params, aot, stream, preempts,
                    ec=None, draft_params=None):
    """Replay a stream through a slotted recurrent engine; ``preempts``
    maps tick -> slot to preempt (empty = the parity reference).  Sweeps
    the allocator-free invariants plus recurrent evict-time zeroing."""
    eng = ServeEngine(
        cfg, mesh, rules, params,
        ec or EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN), aot=aot,
        draft_params=draft_params)
    i, tick, guard = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        eng.step()
        eng.check_invariants()
        slot = preempts.get(tick)
        if slot is not None and eng.slots[slot] is not None:
            eng.preempt(slot)
        tick += 1
        guard += 1
        assert guard < 2000, "engine failed to drain (livelock?)"
    # drained: every lane free and (checked inside, post-decode) every
    # recurrent leaf exactly zero
    assert all(s is None for s in eng.slots)
    eng.check_invariants()
    return [list(eng.completions[r].tokens) for r in range(len(stream))], eng


def test_fuzz_recurrent_preempt_parity(rec_setup):
    cfg, mesh, rules, params, aot = rec_setup
    preempted = replayed = 0
    for seed in range(REC_EPISODES):
        rng = np.random.default_rng(5000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive_recurrent(
            cfg, mesh, rules, params, aot, stream, {})
        preempts = {
            int(t): int(rng.integers(MAX_SLOTS))
            for t in rng.integers(1, 30, size=int(rng.integers(1, 4)))
        }
        got, eng = drive_recurrent(
            cfg, mesh, rules, params, aot, stream, preempts)
        assert got == want, (
            f"episode seed={seed}: preempted {cfg.family} engine diverged"
            f"\n  want={want}\n  got ={got}")
        preempted += eng.counters["preemptions"]
        replayed += eng.counters["replayed_tokens"]
    # the schedule must actually exercise preempt-and-requeue
    if REC_EPISODES >= 5:
        assert preempted > 0, "no recurrent preemption in any episode"
        assert replayed > 0, "no decode replay in any episode"


# ---------------------------------------------------------------------------
# Speculative decoding: draft/verify engines vs the sequential reference
# ---------------------------------------------------------------------------
#
# Greedy spec decoding must be bitwise-invisible: every committed token is
# the target model's argmax over the committed history (drafts only gate
# how MANY positions commit per round, never WHICH token commits), so a
# spec engine's stream equals the plain slotted engine's stream exactly —
# layered on every state kind and on preempt/spill machinery.  The draft
# is the same architecture with params mixed toward a fresh init: close
# enough to accept routinely, far enough to reject routinely, so both the
# commit and the rollback paths are exercised (vacuity-guarded below).

SPEC_K = 3
SPEC_EPISODES = max(2, EPISODES // 10)
SPEC_REC_EPISODES = max(2, EPISODES // 40)


def _draft_mix(cfg, params, alpha):
    """Draft params: target params mixed ``alpha`` toward a fresh init."""
    noise = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(1))
    return jax.tree.map(lambda a, b: (1 - alpha) * a + alpha * b,
                        params, noise)


def spec_modes(cfg):
    """Spec engine configs (need the draft ArchConfig, hence a function)."""
    sp = {"spec_draft": cfg, "spec_k": SPEC_K}
    return {
        "spec_slotted": EngineConfig(
            max_slots=MAX_SLOTS, max_len=MAX_LEN, **sp),
        # paged + prefix: verify rounds cross block boundaries, publish
        # full blocks, and share chains — with the k-token pre-map
        "spec_prefix": EngineConfig(
            max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
            page_size=BS, prefill_chunk=BS, prefix_cache=True, **sp),
        # tight pool: decode growth preempts lanes mid-speculation; the
        # resume replays the COMMITTED stream only
        "spec_preempt": EngineConfig(
            max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
            page_size=BS, num_blocks=6, admission="preempt", **sp),
        # host tier: spec lanes spill O(copy) and resume with the draft
        # cache rebuilt from committed history
        "spec_tiered": EngineConfig(
            max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
            page_size=BS, num_blocks=6, admission="preempt",
            host_tier=True, **sp),
    }


@pytest.fixture(scope="module")
def spec_setup(setup):
    cfg, mesh, rules, params, aot = setup
    return cfg, mesh, rules, params, _draft_mix(cfg, params, 0.15), aot


def test_fuzz_spec_parity(spec_setup):
    cfg, mesh, rules, params, dparams, aot = spec_setup
    agg = {"spec_accepted": 0, "spec_rejected": 0, "preemptions": 0,
           "spills": 0, "restores": 0}
    for seed in range(SPEC_EPISODES):
        rng = np.random.default_rng(3000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"],
                        stream)
        for name, ec in spec_modes(cfg).items():
            got, eng = drive(cfg, mesh, rules, params, aot, ec, stream,
                             draft_params=dparams)
            assert got == want, (
                f"episode seed={seed}: spec engine {name!r} diverged from "
                f"the sequential slotted engine\n  want={want}\n  got ={got}")
            if eng.paged:
                assert eng.alloc.in_use == 0
            if eng.tier is not None:
                eng.tier.check()
                assert eng.tier.spilled_lanes == 0
            for k in agg:
                agg[k] += eng.counters.get(k, 0)
    # both halves of the accept rule must fire, or parity is vacuous:
    # accepted == 0 would reduce every round to sequential decode, and
    # rejected == 0 would never exercise KV truncation / state rollback
    assert agg["spec_accepted"] > 0, "no draft token ever accepted"
    assert agg["spec_rejected"] > 0, "no draft token ever rejected"
    if SPEC_EPISODES >= 10:
        assert agg["preemptions"] > 0, "no preemption hit a spec engine"
        assert agg["spills"] > 0, "no spec lane ever spilled to the tier"
        assert agg["restores"] > 0, "no spec lane ever restored O(copy)"


def test_fuzz_spec_recurrent_parity(rec_setup):
    """Spec decoding over the recurrent state kinds (xLSTM ssm state,
    Zamba's hybrid mamba+KV cache): rejection rolls the recurrent leaves
    back via snapshot/where instead of KV truncation, and host-initiated
    preempts land mid-speculation.  Parity reference: the plain
    (non-spec, non-preempt) engine."""
    cfg, mesh, rules, params, aot = rec_setup
    dparams = _draft_mix(cfg, params, 0.02)
    ec = EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                      spec_draft=cfg, spec_k=SPEC_K)
    accepted = rejected = 0
    for seed in range(SPEC_REC_EPISODES):
        rng = np.random.default_rng(7000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive_recurrent(cfg, mesh, rules, params, aot, stream, {})
        preempts = {
            int(t): int(rng.integers(MAX_SLOTS))
            for t in rng.integers(1, 30, size=int(rng.integers(1, 4)))
        }
        got, eng = drive_recurrent(cfg, mesh, rules, params, aot, stream,
                                   preempts, ec=ec, draft_params=dparams)
        assert got == want, (
            f"episode seed={seed}: spec {cfg.family} engine diverged"
            f"\n  want={want}\n  got ={got}")
        accepted += eng.counters["spec_accepted"]
        rejected += eng.counters["spec_rejected"]
    assert accepted > 0, f"no draft token ever accepted ({cfg.family})"
    assert rejected > 0, f"no draft token ever rejected ({cfg.family})"


def test_fuzz_episode_determinism(setup):
    """The same seed replays to the same stream and the same tokens —
    fuzz failures are reproducible by seed number."""
    cfg, mesh, rules, params, aot = setup
    s1 = make_stream(np.random.default_rng(1000), cfg.vocab)
    s2 = make_stream(np.random.default_rng(1000), cfg.vocab)
    assert len(s1) == len(s2)
    assert all(
        a[0] == b[0] and np.array_equal(a[1], b[1]) and a[2] == b[2]
        for a, b in zip(s1, s2)
    )
    a, _ = drive(cfg, mesh, rules, params, aot, MODES["preempt"], s1)
    b, _ = drive(cfg, mesh, rules, params, aot, MODES["preempt"], s2)
    assert a == b


def test_hypothesis_selection():
    """conftest must install the real ``hypothesis`` when the image ships
    it and the ``_minihypothesis`` stand-in only as a fallback."""
    import importlib.metadata

    import hypothesis

    try:
        importlib.metadata.version("hypothesis")
        real_available = True
    except importlib.metadata.PackageNotFoundError:
        real_available = False
    if real_available:
        assert not getattr(hypothesis, "IS_MINI", False)
        assert hypothesis.__name__ == "hypothesis"
    else:
        assert getattr(hypothesis, "IS_MINI", False)


# ---------------------------------------------------------------------------
# Chaos mode: seeded fault schedules across every engine mode
# ---------------------------------------------------------------------------
#
# The robustness contract on top of parity: with a seeded FaultPlan firing
# at every site (corrupted decode fetches, failed prefill dispatches,
# transient alloc failures, lost sched pushes) plus deadlines and
# mid-flight cancels, ``step()`` never raises, the invariant sweep stays
# clean after every step, every request reaches a terminal status, and —
# the bitwise half — every request that ends "ok" is token-for-token the
# fault-free slotted stream, while non-ok requests hold a prefix of it.
# Retries ride the preempt-and-requeue resume path, so a prebuilt engine
# stays at ``steady_builds_delta == 0`` through arbitrary fault schedules.

import json

import jax.numpy as jnp

from repro.serve import FaultPlan

CHAOS_EPISODES = int(os.environ.get("CHAOS_FUZZ_EPISODES", "6"))
CHAOS_RATES = {"decode_logits": 0.05, "prefill": 0.05, "alloc": 0.03,
               "sched_push": 0.05}


class _FakeClock:
    """Deterministic engine clock: one unit per engine step, advanced by
    the driver — deadline expiry becomes a property of the schedule, not
    of host wall-time."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def drive_chaos(cfg, mesh, rules, params, aot, ec, stream, faults,
                deadline_every=0, cancel_ticks=frozenset(),
                draft_params=None):
    """Replay a stream under a seeded fault schedule; invariants swept
    after every step, and the engine must drain without raising."""
    clock = _FakeClock()
    eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot, faults=faults,
                      clock=clock, draft_params=draft_params)
    i, tick, guard = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            kw = {"deadline_s": 40.0} \
                if deadline_every and i % deadline_every == 0 else {}
            eng.submit(prompt, max_new_tokens=budget, rid=i, **kw)
            i += 1
        if tick in cancel_ticks and eng.live:
            # prefer rids sitting in a race window — queued resumes
            # (between requeue and re-admission) and lanes still
            # replaying their pre-preemption tokens — so cancel lands
            # in the states where refund bugs would actually hide
            resumes = sorted(r.rid for r in eng.queue if r.resume)
            replaying = sorted(s.rid for s in eng.slots
                               if s is not None and s.generated < s.emit_from)
            pool = resumes or replaying or sorted(eng.live)
            eng.cancel(pool[len(pool) // 2])
        eng.step()
        eng.check_invariants()
        clock.t += 1.0
        tick += 1
        guard += 1
        assert guard < 3000, "engine failed to drain under chaos"
    assert not eng.live and not eng.queue
    return eng


def test_chaos_fuzz(setup):
    cfg, mesh, rules, params, aot = setup
    # prebuild every mode's executables: retries and resumes must then
    # dispatch purely from cache (steady_builds_delta == 0 under faults)
    for ec in MODES.values():
        ServeEngine(cfg, mesh, rules, params, ec, aot=aot).prebuild()
    builds0 = aot.stats["builds"]
    agg = {"faults_injected": 0, "faults_detected": 0, "retries": 0,
           "status_ok": 0, "status_timeout": 0, "status_cancelled": 0,
           "status_failed": 0}
    for seed in range(CHAOS_EPISODES):
        rng = np.random.default_rng(9000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot,
                        MODES["slotted"], stream)
        for mi, (name, ec) in enumerate(MODES.items()):
            faults = FaultPlan(seed * len(MODES) + mi, CHAOS_RATES)
            cancel_ticks = {int(t) for t in rng.integers(1, 25, size=2)}
            eng = drive_chaos(cfg, mesh, rules, params, aot, ec, stream,
                              faults, deadline_every=3,
                              cancel_ticks=cancel_ticks)
            for rid in range(len(stream)):
                c = eng.completions[rid]
                assert c.status in ("ok", "timeout", "cancelled", "failed")
                got = list(c.tokens)
                if c.status == "ok":
                    # fault-touched or not: an "ok" request is bitwise
                    # the fault-free stream (retries replay exactly)
                    assert got == want[rid], (
                        f"seed={seed} mode={name} rid={rid}: ok request "
                        f"diverged\n  want={want[rid]}\n  got ={got}")
                else:
                    assert got == want[rid][: len(got)], (
                        f"seed={seed} mode={name} rid={rid}: "
                        f"{c.status} request is not a prefix of the "
                        f"fault-free stream")
            if eng.paged:
                assert eng.alloc.in_use == 0
            st = eng.stats
            for k in agg:
                agg[k] += st[k]
    assert aot.stats["builds"] == builds0, (
        "chaos retries forced fresh compiles — the retry path must reuse "
        "prebuilt executables")
    # the schedule must actually exercise the machinery (vacuity guard)
    assert agg["faults_injected"] > 0, "no faults fired at all"
    assert agg["faults_detected"] > 0, "no sentinel ever detected"
    assert agg["retries"] > 0, "no lane ever retried"
    assert agg["status_ok"] > 0
    if CHAOS_EPISODES >= 4:
        assert agg["status_cancelled"] > 0, "no cancel landed"


def test_chaos_retry_exhaustion_is_structured_failure(setup):
    """A lane that faults on every retry goes terminal with status
    "failed" (data, not an exception), after exactly max_retries + 1
    attempts."""
    cfg, mesh, rules, params, aot = setup
    faults = FaultPlan(1, {"prefill": 1.0})
    eng = ServeEngine(cfg, mesh, rules, params, MODES["slotted"], aot=aot,
                      faults=faults)
    rid = eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=4)
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 100
    c = eng.completions[rid]
    assert c.status == "failed"
    assert c.retries == eng.econ.max_retries + 1
    assert "prefill" in c.error
    assert c.tokens == []
    assert eng.counters["status_failed"] == 1


def test_genuine_nonfinite_logits_detected(setup):
    """Not an injected sentinel: NaN-poisoned weights make the device
    itself produce non-finite logits, the fused program reports the
    sentinel through the ordinary token fetch, and the engine fails the
    request cleanly instead of emitting garbage or raising."""
    cfg, mesh, rules, params, aot = setup
    badp = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), params)
    eng = ServeEngine(cfg, mesh, rules, badp, MODES["paged"], aot=aot)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 100
    c = eng.completions[rid]
    assert c.status == "failed"
    assert "non-finite" in c.error
    assert eng.counters["faults_detected"] > 0
    assert eng.counters["faults_injected"] == 0   # no plan: all genuine
    assert eng.alloc.in_use == 0                  # refs fully refunded


def test_genuine_nonfinite_mid_decode(setup):
    """Weights poisoned AFTER the first token: the prompt prefills
    cleanly, then decode hits non-finite logits mid-stream — the tokens
    emitted before the fault survive on the failed completion."""
    cfg, mesh, rules, params, aot = setup
    eng = ServeEngine(cfg, mesh, rules, params, MODES["slotted"], aot=aot)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=6)
    eng.step()                           # prefill + first decode
    emitted = len(eng.live[rid].tokens)
    assert emitted >= 1
    eng.params = jax.device_put(
        jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), eng.params),
        eng._p_sh)
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 100
    c = eng.completions[rid]
    assert c.status == "failed"
    assert "decode" in c.error or "prefill" in c.error
    assert len(c.tokens) >= emitted               # pre-fault emissions kept
    assert eng.counters["faults_detected"] > 0


def test_chaos_snapshot_kill_restore(setup):
    """Kill-and-restore mid-episode: snapshot the engine's host truth at
    an arbitrary step, rebuild a FRESH engine from the (JSON round-
    tripped) snapshot, finish the stream there — bitwise identical to the
    uninterrupted run, with no new executable builds."""
    cfg, mesh, rules, params, aot = setup
    for name in ("slotted", "paged_chunked", "prefix_preempt"):
        ec = MODES[name]
        stream = make_stream(np.random.default_rng(777), cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot,
                        MODES["slotted"], stream)
        for kill_tick in (1, 3, 6):
            eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
            eng.prebuild()
            builds0 = aot.stats["builds"]
            i, tick = 0, 0
            while tick < kill_tick and (i < len(stream) or eng.has_work()):
                while i < len(stream) and stream[i][0] <= tick:
                    _, prompt, budget = stream[i]
                    eng.submit(prompt, max_new_tokens=budget, rid=i)
                    i += 1
                eng.step()
                tick += 1
            # crash boundary: only what snapshot() serialized survives
            snap = json.loads(json.dumps(eng.snapshot()))
            del eng
            eng2 = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
            eng2.restore(snap)
            guard = 0
            while i < len(stream) or eng2.has_work():
                while i < len(stream) and stream[i][0] <= tick:
                    _, prompt, budget = stream[i]
                    eng2.submit(prompt, max_new_tokens=budget, rid=i)
                    i += 1
                eng2.step()
                eng2.check_invariants()
                tick += 1
                guard += 1
                assert guard < 2000
            got = [list(eng2.completions[r].tokens)
                   for r in range(len(stream))]
            assert got == want, (
                f"mode={name} kill_tick={kill_tick}: restored engine "
                f"diverged\n  want={want}\n  got ={got}")
            assert all(c.status == "ok"
                       for c in eng2.completions.values())
            assert eng2.counters["snapshot_restores"] == 1
            assert aot.stats["builds"] == builds0, (
                "restore forced fresh compiles")
