"""Request-lifecycle, snapshot/restore, and checkpoint fault tolerance.

Complements the chaos sweep in test_engine_fuzz.py with targeted
coverage: deadline expiry in-queue vs mid-decode, cancel() resource
refunds under the paged+prefix engine, cancel/expiry landing inside the
preempt-and-requeue and chunked-prefill race windows, engine snapshot
round-trips through CheckpointManager on disk, crash-mid-save atomicity,
save retry-with-backoff, async-save error surfacing, the train-side
non-finite skip-step, and the elastic ZeRO reshard restore.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.checkpoint.manager as manager_mod
from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core.aot import AotCache
from repro.launch.mesh import _mk, single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.optim import OptConfig
from repro.optim.buckets import (
    make_buckets,
    rescatter_flat,
    reshard_scattered,
    resolve_bucket_bytes,
    unscatter_flat,
)
from repro.optim.flat import make_layout
from repro.serve import EngineConfig, ServeEngine
from repro.train import LoopConfig, TrainSettings, train
from repro.train.step import build_train_step, flat_layout_for, opt_state_template


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def serve_setup():
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params, AotCache("ft")


def _mk_engine(serve_setup, ec, **kw):
    cfg, mesh, rules, params, aot = serve_setup
    return ServeEngine(cfg, mesh, rules, params, ec, aot=aot, **kw)


PAGED_PREFIX = EngineConfig(
    max_slots=2, max_len=48, kv_layout="paged", page_size=8,
    prefix_cache=True)


# ---------------------------------------------------------------------------
# Deadlines and cancel
# ---------------------------------------------------------------------------


def test_deadline_expiry_queued_vs_mid_decode(serve_setup):
    """A queued request expires with zero tokens; a decoding request
    expires mid-stream keeping what it emitted — both with full resource
    refund and no exception out of step()."""
    clock = FakeClock()
    eng = _mk_engine(
        serve_setup, EngineConfig(max_slots=1, max_len=48), clock=clock)
    r0 = eng.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=20,
                    deadline_s=4.0)
    r1 = eng.submit(np.arange(3, 9, dtype=np.int32), max_new_tokens=20,
                    deadline_s=2.0)   # never gets the single lane
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        clock.t += 1.0
        guard += 1
        assert guard < 100
    c0, c1 = eng.completions[r0], eng.completions[r1]
    assert c1.status == "timeout" and c1.tokens == []
    assert c0.status == "timeout" and 0 < len(c0.tokens) < 20
    assert eng.counters["status_timeout"] == 2
    # a request that fits its deadline still finishes ok
    r2 = eng.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=2,
                    deadline_s=50.0)
    eng.drain()
    assert eng.completions[r2].status == "ok"
    assert len(eng.completions[r2].tokens) == 2


def test_cancel_refunds_blocks_and_deficit(serve_setup):
    """cancel() under paged+prefix+deficit: mid-decode cancel drops the
    lane's block refs and refunds its worst-case commitment; queued
    cancel never touches the pool; neighbors are unaffected."""
    pre = np.arange(1, 9, dtype=np.int32)          # one full shared block
    p0 = np.concatenate([pre, [11, 12]]).astype(np.int32)
    p1 = np.concatenate([pre, [21, 22, 23]]).astype(np.int32)
    p2 = np.arange(31, 38, dtype=np.int32)

    solo = _mk_engine(serve_setup, PAGED_PREFIX)
    want1 = list(solo.run([p1], max_new_tokens=6)[0])

    eng = _mk_engine(serve_setup, PAGED_PREFIX)
    # r0's worst case spans 4 blocks but its prompt maps only 2 — a
    # mid-decode cancel must refund the outstanding commitment
    r0 = eng.submit(p0, max_new_tokens=20)
    r1 = eng.submit(p1, max_new_tokens=6)
    r2 = eng.submit(p2, max_new_tokens=6)          # queued (2 lanes)
    for _ in range(3):
        eng.step()
    assert any(s is not None and s.rid == r0 for s in eng.slots)
    deficit_before = eng._deficit
    assert deficit_before > 0
    assert eng.cancel(r0) is True                  # mid-decode
    assert eng._deficit < deficit_before           # commitment refunded
    eng.check_invariants()
    assert eng.cancel(r2) is True                  # still queued
    eng.check_invariants()
    eng.drain()
    assert eng.completions[r0].status == "cancelled"
    assert eng.completions[r2].status == "cancelled"
    assert eng.completions[r2].tokens == []
    assert eng.completions[r1].status == "ok"
    assert list(eng.completions[r1].tokens) == want1
    assert eng.counters["status_cancelled"] == 2
    assert eng.alloc.in_use == 0                   # every ref returned
    eng.check_invariants()
    # terminal states are idempotent / unknown rids loud
    assert eng.cancel(r1) is False
    with pytest.raises(KeyError):
        eng.cancel(12345)


# ---------------------------------------------------------------------------
# Race windows: preempt-and-requeue replay, chunked prefill
# ---------------------------------------------------------------------------


def test_cancel_in_preempt_requeue_window(serve_setup):
    """Cancel landing in the window between requeue and re-admission: a
    preempted lane sits in the queue as a resume holding its emitted
    tokens but no device resources.  The completion keeps exactly the
    pre-preemption tokens, every block ref and the deficit commitment
    refund, and the surviving lane's stream is untouched."""
    p0 = np.arange(1, 9, dtype=np.int32)
    p1 = np.arange(21, 27, dtype=np.int32)
    solo = _mk_engine(serve_setup, PAGED_PREFIX)
    want0 = list(solo.run([p0], max_new_tokens=12)[0])
    want1 = list(solo.run([p1], max_new_tokens=6)[0])

    eng = _mk_engine(serve_setup, PAGED_PREFIX)
    r0 = eng.submit(p0, max_new_tokens=12)
    r1 = eng.submit(p1, max_new_tokens=6)
    guard = 0
    while r0 not in eng.live or len(eng.live[r0].tokens) < 2:
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 50
    slot = next(i for i, s in enumerate(eng.slots)
                if s is not None and s.rid == r0)
    n_pre = len(eng.live[r0].tokens)
    eng.preempt(slot)
    eng.check_invariants()
    assert eng.queue[0].rid == r0 and eng.queue[0].resume
    assert eng.cancel(r0) is True             # cancelled inside the window
    eng.check_invariants()
    eng.drain()
    c0 = eng.completions[r0]
    assert c0.status == "cancelled"
    assert list(c0.tokens) == want0[:n_pre]   # kept what it had emitted
    assert eng.completions[r1].status == "ok"
    assert list(eng.completions[r1].tokens) == want1
    assert eng.alloc.in_use == 0 and eng._deficit == 0
    eng.check_invariants()


def test_deadline_expires_in_preempt_requeue_window(serve_setup):
    """A preempted request whose deadline passes while it waits in the
    queue as a resume: the sweep terminates it with "timeout" keeping
    its pre-preemption tokens, with the full resource refund."""
    clock = FakeClock()
    eng = _mk_engine(serve_setup, PAGED_PREFIX, clock=clock)
    r0 = eng.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=12,
                    deadline_s=100.0)
    guard = 0
    while r0 not in eng.live or len(eng.live[r0].tokens) < 2:
        eng.step()
        eng.check_invariants()
        clock.t += 1.0
        guard += 1
        assert guard < 50
    n_pre = len(eng.live[r0].tokens)
    slot = next(i for i, s in enumerate(eng.slots)
                if s is not None and s.rid == r0)
    eng.preempt(slot)
    eng.check_invariants()
    assert eng.queue[0].resume
    clock.t += 200.0                          # expire it inside the window
    eng.drain()
    c0 = eng.completions[r0]
    assert c0.status == "timeout"
    assert len(c0.tokens) == n_pre            # replay never re-ran
    assert eng.counters["status_timeout"] == 1
    assert eng.alloc.in_use == 0 and eng._deficit == 0
    eng.check_invariants()


def test_cancel_mid_replay(serve_setup):
    """Cancel a lane while it is still replaying its pre-preemption
    tokens (generated < emit_from): the replay stops, the completion
    holds exactly the already-emitted tokens (no duplicates, no loss),
    and the lane's blocks and commitment refund."""
    p0 = np.arange(1, 9, dtype=np.int32)
    solo = _mk_engine(serve_setup, PAGED_PREFIX)
    want0 = list(solo.run([p0], max_new_tokens=12)[0])

    eng = _mk_engine(serve_setup, PAGED_PREFIX)
    r0 = eng.submit(p0, max_new_tokens=12)
    guard = 0
    while r0 not in eng.live or len(eng.live[r0].tokens) < 3:
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 50
    n_pre = len(eng.live[r0].tokens)
    slot = next(i for i, s in enumerate(eng.slots)
                if s is not None and s.rid == r0)
    eng.preempt(slot)
    # step until the resume is back on a lane mid-replay
    guard = 0
    while True:
        eng.step()
        eng.check_invariants()
        s = next((s for s in eng.slots
                  if s is not None and s.rid == r0), None)
        if s is not None and 0 < s.generated < s.emit_from:
            break
        guard += 1
        assert guard < 50, "never observed the replay window"
    assert eng.cancel(r0) is True             # cancelled mid-replay
    eng.check_invariants()
    eng.drain()
    c0 = eng.completions[r0]
    assert c0.status == "cancelled"
    assert list(c0.tokens) == want0[:n_pre]   # replay added nothing twice
    assert eng.alloc.in_use == 0 and eng._deficit == 0
    eng.check_invariants()


CHUNKED = dataclasses.replace(PAGED_PREFIX, prefill_chunk=8)


def test_cancel_and_expiry_mid_chunked_prefill(serve_setup):
    """Cancel one request and expire another while their prompts are
    only partially prefilled (prefilled < plen): both evict with zero
    tokens and a full refund of the blocks their chunks had mapped."""
    clock = FakeClock()
    eng = _mk_engine(serve_setup, CHUNKED, clock=clock)
    r0 = eng.submit(np.arange(1, 21, dtype=np.int32), max_new_tokens=4)
    r1 = eng.submit(np.arange(5, 25, dtype=np.int32), max_new_tokens=4,
                    deadline_s=0.5)
    eng.step()                                # first chunk of each lane
    eng.check_invariants()
    mid = [s for s in eng.slots if s is not None and s.prefilled < s.plen]
    assert {s.rid for s in mid} == {r0, r1}, "not mid-prefill: bad setup"
    assert eng.cancel(r0) is True             # cancelled mid-chunked-prefill
    eng.check_invariants()
    clock.t += 1.0                            # r1 expires mid-chunked-prefill
    eng.drain()
    assert eng.completions[r0].status == "cancelled"
    assert eng.completions[r1].status == "timeout"
    assert eng.completions[r0].tokens == []
    assert eng.completions[r1].tokens == []
    assert eng.alloc.in_use == 0 and eng._deficit == 0
    eng.check_invariants()
    # the engine is still healthy afterwards
    out = eng.run([np.arange(1, 6, dtype=np.int32)], max_new_tokens=3)
    assert len(out[0]) == 3


# ---------------------------------------------------------------------------
# Engine snapshot / restore
# ---------------------------------------------------------------------------


def test_snapshot_restore_roundtrip_through_disk(serve_setup, tmp_path):
    """Mid-episode snapshot -> CheckpointManager (atomic on-disk write)
    -> fresh engine -> drain: bitwise the uninterrupted run, and the
    restored engine's own snapshot equals the saved one (idempotence)."""
    prompts = [np.arange(1, 1 + n, dtype=np.int32) for n in (5, 9, 13, 7)]
    ref = _mk_engine(serve_setup, PAGED_PREFIX)
    want = [list(t) for t in ref.run(prompts, max_new_tokens=6)]

    eng = _mk_engine(serve_setup, PAGED_PREFIX)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=6, rid=i)
    for _ in range(3):
        eng.step()
    mgr = CheckpointManager(str(tmp_path))
    eng.save_snapshot(mgr, 7)
    saved = eng.snapshot()
    del eng

    eng2 = _mk_engine(serve_setup, PAGED_PREFIX)
    assert eng2.restore_snapshot(mgr) == 7
    again = eng2.snapshot()
    for k in saved:
        if k != "counters":       # snapshot_restores differs, rest rides
            assert again[k] == saved[k], f"snapshot not idempotent at {k}"
    eng2.drain()
    eng2.check_invariants()
    got = [list(eng2.completions[r].tokens) for r in range(len(prompts))]
    assert got == want
    assert all(c.status == "ok" for c in eng2.completions.values())


def test_restore_guards(serve_setup):
    eng = _mk_engine(serve_setup, PAGED_PREFIX)
    eng.submit(np.arange(1, 6, dtype=np.int32), max_new_tokens=3)
    snap = eng.snapshot()
    # restore target must be fresh
    with pytest.raises(ValueError, match="fresh"):
        eng.restore(snap)
    # and must match the snapshot's EngineConfig
    other = _mk_engine(
        serve_setup, dataclasses.replace(PAGED_PREFIX, max_slots=3))
    with pytest.raises(ValueError, match="EngineConfig"):
        other.restore(snap)
    bad = dict(snap, format=99)
    fresh = _mk_engine(serve_setup, PAGED_PREFIX)
    with pytest.raises(ValueError, match="format"):
        fresh.restore(bad)
    fresh.restore(snap)           # fresh + matching: fine
    fresh.drain()
    assert fresh.completions[0].status == "ok"


# ---------------------------------------------------------------------------
# CheckpointManager hardening
# ---------------------------------------------------------------------------


def test_crash_mid_save_restores_previous_step(tmp_path, monkeypatch):
    """Die between the tmp write and the atomic rename: the previous
    checkpoint stays the latest restorable state, and the orphaned tmp
    dir is swept by the next manager."""
    d = str(tmp_path)
    tree = {"a": jnp.arange(3.0)}
    mgr = CheckpointManager(d)
    mgr.save(1, {"params": tree})
    with monkeypatch.context() as m:
        m.setattr(manager_mod.os, "rename",
                  lambda *a: (_ for _ in ()).throw(OSError("killed")))
        with pytest.raises(OSError):
            mgr.save(2, {"params": jax.tree.map(lambda x: x * 2, tree)})
    assert os.path.isdir(os.path.join(d, ".tmp-2"))   # the orphan
    step, state = mgr.restore({"params": tree})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["params"]["a"]),
                                  np.arange(3.0))
    mgr2 = CheckpointManager(d)                       # init sweeps tmps
    assert not any(f.startswith(".tmp") for f in os.listdir(d))
    assert mgr2.latest_step() == 1


def test_async_save_failure_reraises(tmp_path, monkeypatch):
    """A failed background save must not be silent: the exception
    surfaces at the next wait() (or save(), which waits first)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones(2)}
    with monkeypatch.context() as m:
        m.setattr(manager_mod.np, "savez",
                  lambda *a, **k: (_ for _ in ()).throw(OSError("disk full")))
        mgr.save(1, {"params": tree}, blocking=False)
        with pytest.raises(RuntimeError, match="async checkpoint save"):
            mgr.wait()
    mgr.wait()                    # error consumed, manager usable again
    with monkeypatch.context() as m:
        m.setattr(manager_mod.np, "savez",
                  lambda *a, **k: (_ for _ in ()).throw(OSError("disk full")))
        mgr.save(2, {"params": tree}, blocking=False)
        with pytest.raises(RuntimeError, match="async checkpoint save"):
            mgr.save(3, {"params": tree})             # save() waits first
    mgr.save(4, {"params": tree})
    assert mgr.latest_step() == 4


def test_save_retries_transient_io(tmp_path, monkeypatch):
    """Two ENOSPC blips then a healthy disk: save() succeeds on the
    third attempt, backing off exponentially through the injectable
    sleep (no real-time wait), and the checkpoint round-trips."""
    sleeps = []
    mgr = CheckpointManager(str(tmp_path), save_retries=3,
                            retry_backoff_s=0.01, sleep=sleeps.append)
    tree = {"a": jnp.arange(4.0)}
    real = manager_mod.np.savez
    calls = {"n": 0}

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("ENOSPC")
        return real(*a, **k)

    with monkeypatch.context() as m:
        m.setattr(manager_mod.np, "savez", flaky)
        mgr.save(1, {"params": tree})
    assert calls["n"] == 3
    assert sleeps == [0.01, 0.02]
    assert mgr.latest_step() == 1
    step, state = mgr.restore({"params": tree})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["params"]["a"]),
                                  np.arange(4.0))


def test_save_retry_exhaustion_still_raises(tmp_path, monkeypatch):
    """A persistent failure surfaces after the retry budget is spent —
    exactly save_retries attempts, save_retries - 1 backoffs, and no
    checkpoint left behind pretending to exist."""
    sleeps = []
    mgr = CheckpointManager(str(tmp_path), save_retries=2,
                            retry_backoff_s=0.01, sleep=sleeps.append)
    with monkeypatch.context() as m:
        m.setattr(manager_mod.os, "rename",
                  lambda *a: (_ for _ in ()).throw(OSError("gone")))
        with pytest.raises(OSError):
            mgr.save(1, {"params": {"a": jnp.ones(2)}})
    assert sleeps == [0.01]
    assert mgr.latest_step() is None
    with pytest.raises(ValueError, match="save_retries"):
        CheckpointManager(str(tmp_path), save_retries=0)


def test_async_save_absorbs_transient_blip(tmp_path, monkeypatch):
    """A transient I/O blip during a background save is absorbed by the
    retry loop — wait() sees success, not the RuntimeError."""
    mgr = CheckpointManager(str(tmp_path), save_retries=2,
                            retry_backoff_s=0.0, sleep=lambda s: None)
    real = manager_mod.np.savez
    calls = {"n": 0}

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("blip")
        return real(*a, **k)

    with monkeypatch.context() as m:
        m.setattr(manager_mod.np, "savez", flaky)
        mgr.save(1, {"params": {"a": jnp.ones(2)}}, blocking=False)
        mgr.wait()                # no raise: the retry absorbed the blip
    assert calls["n"] == 2
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# Train-side non-finite gradient guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["faithful", "zero"])
def test_skip_step_is_bitwise_noop(mode):
    mesh = single_device_mesh() if mode == "faithful" \
        else _mk((1, 1), ("data", "model"))
    rules = ShardRules.for_mesh(mesh, faithful=(mode == "faithful"))
    cfg = get_smoke_config("smollm-360m")
    opt = OptConfig(kind="adam", lr=1e-3, bucket_mb=0.05)
    tset = TrainSettings(faithful=(mode == "faithful"),
                         flat_engine="auto" if mode == "faithful" else "zero")
    step = jax.jit(build_train_step(cfg, mesh, rules, opt, tset))
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    init_fn, _ = opt_state_template(cfg, mesh, rules, opt, tset)
    opt_state = init_fn(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (4, 17)), jnp.int32)}

    p1, o1, m1 = step(params, opt_state, batch)
    assert float(m1["skipped"]) == 0.0
    assert int(o1["step"]) == 1

    # poison one weight -> NaN loss -> non-finite flat gradient
    leaves, tree = jax.tree.flatten(params)
    badp = jax.tree.unflatten(
        tree, [leaves[0].at[(0,) * leaves[0].ndim].set(jnp.inf)] + leaves[1:])
    p2, o2, m2 = step(badp, o1, batch)
    assert float(m2["skipped"]) == 1.0
    assert int(o2["step"]) == 1               # Adam bias step frozen
    for a, b in zip(jax.tree.leaves(badp), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
    for k in ("m", "v"):
        for a, b in zip(jax.tree.leaves(o1[k]), jax.tree.leaves(o2[k])):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                f"{k} mutated by a skipped step"


def test_loop_reports_skipped_steps(tmp_path):
    mesh = _mk((1, 1), ("data", "model"))
    rules = ShardRules.for_mesh(mesh)
    cfg = get_smoke_config("smollm-360m")
    res = train(cfg, ShapeConfig("t", "train", 16, 8), mesh, rules,
                OptConfig(kind="adam", lr=1e-2, bucket_mb=0.05),
                TrainSettings(flat_engine="zero"),
                LoopConfig(steps=2, ckpt_every=0, log_every=0))
    assert res["skipped_steps"] == 0          # healthy run: none skipped


# ---------------------------------------------------------------------------
# Elastic ZeRO restore (dp resize)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(sizes=st.lists(st.integers(1, 50), min_size=1, max_size=5),
       seed=st.integers(0, 10**6))
def test_reshard_scattered_dp8_to_dp4_property(sizes, seed):
    """Bucket-major scattered buffers re-lay exactly across dp sizes:
    reshard(scatter_dp8(x)) == scatter_dp4(x) bitwise, and unscatter
    inverts rescatter."""
    rng = np.random.default_rng(seed)
    tree = {f"p{i}": np.zeros((s,), np.float32) for i, s in enumerate(sizes)}
    layout = make_layout(tree)
    flat = rng.standard_normal(layout.total).astype(np.float32)
    b8 = make_buckets(layout, bucket_bytes=64, n_shards=8)
    b4 = make_buckets(layout, bucket_bytes=64, n_shards=4)
    s8 = rescatter_flat(flat, b8)
    assert np.array_equal(unscatter_flat(s8, b8), flat)
    assert np.array_equal(reshard_scattered(s8, b8, b4),
                          rescatter_flat(flat, b4))
    assert np.array_equal(reshard_scattered(rescatter_flat(flat, b4), b4, b8),
                          s8)


def test_elastic_zero_restore_end_to_end(tmp_path):
    """Resume a ZeRO run from a checkpoint whose scattered m/v were laid
    out for dp=8: the loop reshards host-side and the continued run is
    bitwise the uninterrupted one."""
    mesh = _mk((1, 1), ("data", "model"))
    rules = ShardRules.for_mesh(mesh)
    cfg = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", "train", 16, 8)
    opt = OptConfig(kind="adam", lr=1e-2, bucket_mb=0.05)
    tset = TrainSettings(flat_engine="zero")
    d1, d2 = str(tmp_path / "dp1"), str(tmp_path / "dp8")

    ref = train(cfg, shape, mesh, rules, opt, tset,
                LoopConfig(steps=6, ckpt_every=3, ckpt_dir=d1, log_every=0))

    # rewrite the step-3 checkpoint as a dp=8 job would have saved it
    layout = flat_layout_for(cfg)
    bb = resolve_bucket_bytes(opt.bucket_mb, group_size=1)
    b1 = make_buckets(layout, bucket_bytes=bb, n_shards=1)
    b8 = make_buckets(layout, bucket_bytes=bb, n_shards=8)
    f32 = lambda n: jax.ShapeDtypeStruct((n,), jnp.float32)
    tmpl = {"params": registry.abstract_params(cfg),
            "opt": {"step": jax.ShapeDtypeStruct((), jnp.int32),
                    "m": f32(b1.scattered_total),
                    "v": f32(b1.scattered_total)}}
    step3, state = CheckpointManager(d1).restore(tmpl, step=3)
    assert step3 == 3
    CheckpointManager(d2).save(3, {
        "params": state["params"],
        "opt": {"step": state["opt"]["step"],
                "m": reshard_scattered(state["opt"]["m"], b1, b8),
                "v": reshard_scattered(state["opt"]["v"], b1, b8)},
    }, extra_meta={"flat_engine": "zero", "zero_n_shards": 8,
                   "zero_bucket_bytes": bb})

    res = train(cfg, shape, mesh, rules, opt, tset,
                LoopConfig(steps=6, ckpt_every=0, ckpt_dir=d2, log_every=0))
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(res["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "resharded resume diverged from the uninterrupted run"
