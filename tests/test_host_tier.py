"""Host-RAM KV/state tiering: spill/restore correctness and conservation.

The tier's contract has three legs, each tested here:

1. **O(copy) resume is behavior-invisible** — a lane restored from its
   host spill continues bitwise-identically to a never-preempted run,
   with zero replay decode steps for the covered tokens (the payload IS
   the evicted state, so this is exact, not approximate).
2. **Four-state conservation** — ``free + live + cached + spilled ==
   capacity`` holds across the device pool and the host tier after
   every step of arbitrary preempt/hold/park/release schedules
   (:func:`repro.serve.paged.check_tiered`, swept by the engine's own
   ``check_invariants``), and every chain key has exactly one owner.
3. **Graceful refusal** — a bounded tier that cannot make room drops
   the spill and the resume falls back to decode replay; correctness
   never depends on host capacity.

The schedules are drawn through ``hypothesis`` (the image's real
package when present, ``tests/_minihypothesis.py`` otherwise — see
``test_engine_fuzz.test_hypothesis_selection``) across four layouts:
slotted KV, paged with preemption, paged with a bounded tier + prefix
cache, and slotted recurrent (xLSTM) where hold() must snapshot
immediately because the decode freeze zeroes inactive lanes' state.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.models import registry
from repro.serve import EngineConfig, HostTier, LaneSpill, ServeEngine

MAX_SLOTS, MAX_LEN, BS = 3, 48, 8

LAYOUTS = {
    "slotted": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                            host_tier=True),
    "paged": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, admission="preempt", host_tier=True),
    # bounded tier + prefix cache: lane spills compete with spilled
    # chains for 8 block units, so refusals/drops fire and resumes must
    # fall back to replay without losing parity
    "bounded_prefix": EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, kv_layout="paged",
        page_size=BS, num_blocks=6, prefix_cache=True,
        admission="preempt", host_tier=True, host_tier_blocks=8),
    "recurrent": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                              host_tier=True, park_idle_s=4.0),
}
# the parity reference per layout: same engine family, no tier, no
# schedule interference
REFS = {
    "slotted": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
    "paged": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
    "bounded_prefix": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
    "recurrent": EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
}
ARCH = {"slotted": "smollm-360m", "paged": "smollm-360m",
        "bounded_prefix": "smollm-360m", "recurrent": "xlstm-1.3b"}


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def setups():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    out = {}
    for arch in sorted(set(ARCH.values())):
        cfg = dataclasses.replace(
            get_smoke_config(arch), compute_dtype="float32")
        params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
        out[arch] = (cfg, mesh, rules, params, AotCache(f"tier-{arch}"))
    return out


def make_stream(rng, vocab):
    out, tick = [], 0
    for _ in range(int(rng.integers(3, 7))):
        tick += int(rng.integers(0, 3))
        plen = int(rng.integers(2, 20))
        prompt = rng.integers(0, vocab, plen).astype(np.int32)
        budget = int(rng.integers(2, 8))
        out.append((tick, prompt[: MAX_LEN - budget - BS], budget))
    return out


def drive_ref(setups, layout, stream):
    cfg, mesh, rules, params, aot = setups[ARCH[layout]]
    eng = ServeEngine(cfg, mesh, rules, params, REFS[layout], aot=aot)
    guard, i, tick = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        eng.step()
        tick += 1
        guard += 1
        assert guard < 2000
    return [list(eng.completions[r].tokens) for r in range(len(stream))]


def apply_op(eng, op, rng):
    """One schedule op against a live engine; silently skips when the
    op's precondition doesn't hold (no decoding lane to preempt, nothing
    held, ...) — the schedule is adversarial, not scripted."""
    decoding = [i for i, s in enumerate(eng.slots)
                if s is not None and s.prefilled >= s.plen
                and s.generated >= 1 and not s.held]
    held = [s.rid for s in eng.slots if s is not None and s.held]
    if op == "preempt" and decoding:
        eng.preempt(int(rng.choice(decoding)))
    elif op == "hold" and decoding:
        eng.hold(eng.slots[int(rng.choice(decoding))].rid)
    elif op == "release":
        pool = held + sorted(eng.parked)
        if pool:
            eng.release(int(rng.choice(pool)))
    elif op == "idle":
        # long-idle: the park sweep (when configured) moves held lanes
        # off-HBM on the next step
        eng.clock.t += 5.0


@settings(max_examples=4)
@given(layout=st.sampled_from(sorted(LAYOUTS)),
       seed=st.integers(0, 10_000),
       ops=st.lists(st.sampled_from(
           ["step", "step", "preempt", "hold", "release", "idle"]),
           min_size=6, max_size=20))
def test_spill_restore_schedules(setups, layout, seed, ops):
    """Random preempt/hold/park/release schedules across every layout:
    conservation after every step, bitwise token parity at the end."""
    cfg, mesh, rules, params, aot = setups[ARCH[layout]]
    rng = np.random.default_rng(seed)
    stream = make_stream(rng, cfg.vocab)
    want = drive_ref(setups, layout, stream)
    clock = _FakeClock()
    eng = ServeEngine(cfg, mesh, rules, params, LAYOUTS[layout], aot=aot,
                      clock=clock)
    i, tick, guard = 0, 0, 0
    schedule = list(ops)
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        if schedule:
            op = schedule.pop()
            if op != "step":
                apply_op(eng, op, rng)
        elif any(s is not None and s.held for s in eng.slots) or eng.parked:
            # schedule exhausted: release everything so the drain ends
            for s in list(eng.slots):
                if s is not None and s.held:
                    eng.release(s.rid)
            for rid in sorted(eng.parked):
                eng.release(rid)
        eng.step()
        eng.check_invariants()      # includes check_tiered + tier.check
        clock.t += 1.0
        tick += 1
        guard += 1
        assert guard < 2000, "tiered engine failed to drain"
    got = [list(eng.completions[r].tokens) for r in range(len(stream))]
    assert got == want, (
        f"layout={layout} seed={seed} ops={ops}: tiered schedule "
        f"diverged\n  want={want}\n  got ={got}")
    assert eng.tier.spilled_lanes == 0      # every spill consumed/dropped
    assert all(c.status == "ok" for c in eng.completions.values())


# ---------------------------------------------------------------------------
# Targeted lifecycle: hold / park / release
# ---------------------------------------------------------------------------


def _drive(eng, stream, clock=None, hook=None):
    i, tick, guard = 0, 0, 0
    while i < len(stream) or eng.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            eng.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        if hook is not None:
            hook(eng, tick)
        eng.step()
        eng.check_invariants()
        if clock is not None:
            clock.t += 1.0
        tick += 1
        guard += 1
        assert guard < 2000
    return [list(eng.completions[r].tokens) for r in range(len(stream))]


def test_park_is_o_copy_not_replay(setups):
    """A lane held past park_idle_s parks off-HBM (its slot frees), and
    release restores it from the tier with ZERO replayed tokens — the
    resume is O(bytes copied), not O(generated)."""
    cfg, mesh, rules, params, aot = setups["smollm-360m"]
    ec = dataclasses.replace(LAYOUTS["paged"], park_idle_s=4.0)
    stream = [(0, np.arange(1, 13, dtype=np.int32), 8)]
    want = drive_ref(setups, "paged", stream)
    clock = _FakeClock()
    eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot, clock=clock)
    state = {"parked": False}

    def hook(eng, tick):
        if tick == 2:
            assert eng.hold(0)
            clock.t += 10.0                 # idle past the threshold
        if eng.parked and not state["parked"]:
            state["parked"] = True
            assert all(s is None for s in eng.slots)    # slot reclaimed
            eng.release(0)

    got = _drive(eng, stream, clock=clock, hook=hook)
    assert got == want
    assert state["parked"], "the park sweep never fired"
    assert eng.counters["parked"] == 1
    assert eng.counters["spills"] >= 1
    assert eng.counters["restores"] >= 1
    assert eng.counters["replayed_tokens"] == 0, (
        "a parked lane's resume replayed decode steps — the restore "
        "must be O(copy)")
    assert eng.counters["preemptions"] == 0     # park is not a preempt


def test_hold_release_kv_keeps_device_state(setups):
    """A held KV lane stays device-resident: release flips the active
    bit back with no restore, no replay, and the stream is bitwise the
    uninterrupted one."""
    cfg, mesh, rules, params, aot = setups["smollm-360m"]
    stream = [(0, np.arange(1, 10, dtype=np.int32), 6)]
    want = drive_ref(setups, "slotted", stream)
    eng = ServeEngine(cfg, mesh, rules, params, LAYOUTS["slotted"], aot=aot)

    def hook(eng, tick):
        if tick == 2:
            eng.hold(0)
        if tick == 5:
            eng.release(0)

    got = _drive(eng, stream, hook=hook)
    assert got == want
    assert eng.counters["holds"] == 1 and eng.counters["releases"] == 1
    assert eng.counters["restores"] == 0        # KV hold: state never left
    # held ticks made no progress on the lane
    assert eng.counters["replayed_tokens"] == 0


def test_hold_recurrent_spills_immediately(setups):
    """Recurrent lanes CANNOT be held in place — the decode freeze
    zeroes inactive lanes' recurrent leaves — so hold() snapshots to the
    tier at hold time and release restores it; parity is bitwise."""
    cfg, mesh, rules, params, aot = setups["xlstm-1.3b"]
    stream = [(0, np.arange(1, 10, dtype=np.int32), 6),
              (1, np.arange(3, 14, dtype=np.int32), 5)]
    want = drive_ref(setups, "recurrent", stream)
    clock = _FakeClock()
    eng = ServeEngine(cfg, mesh, rules, params, LAYOUTS["recurrent"],
                      aot=aot, clock=clock)

    def hook(eng, tick):
        if tick == 2 and eng.slots[0] is not None:
            eng.hold(eng.slots[0].rid)
            assert eng.counters["spills"] == 1, (
                "recurrent hold must spill at hold() time — the device "
                "copy is zeroed by the next decode's freeze")
        if tick == 4 and eng.slots[0] is not None and eng.slots[0].held:
            eng.release(eng.slots[0].rid)

    got = _drive(eng, stream, clock=clock, hook=hook)
    assert got == want
    assert eng.counters["restores"] >= 1
    assert eng.counters["replayed_tokens"] == 0


def test_hold_recurrent_without_tier_raises(setups):
    cfg, mesh, rules, params, aot = setups["xlstm-1.3b"]
    eng = ServeEngine(cfg, mesh, rules, params, REFS["recurrent"], aot=aot)
    eng.submit(np.arange(1, 8, dtype=np.int32), max_new_tokens=4)
    eng.step()
    with pytest.raises(ValueError, match="host tier"):
        eng.hold(0)
    while eng.has_work():
        eng.step()


def test_preempted_lane_restores_without_replay(setups):
    """THE tentpole property, stated directly: preempt a mid-decode
    paged lane, and its resume must restore O(copy) — zero replayed
    decode tokens, zero re-prefilled chunks for covered positions —
    yet produce the bitwise-identical stream."""
    cfg, mesh, rules, params, aot = setups["smollm-360m"]
    stream = [(0, np.arange(1, 13, dtype=np.int32), 8)]
    want = drive_ref(setups, "paged", stream)
    eng = ServeEngine(cfg, mesh, rules, params, LAYOUTS["paged"], aot=aot)

    def hook(eng, tick):
        if tick == 3 and eng.slots[0] is not None \
                and eng.slots[0].generated >= 2:
            eng.preempt(0)

    got = _drive(eng, stream, hook=hook)
    assert got == want
    assert eng.counters["preemptions"] == 1
    assert eng.counters["spills"] == 1 and eng.counters["restores"] == 1
    assert eng.counters["replayed_tokens"] == 0
    assert eng.counters["restored_bytes"] > 0


def test_host_tier_second_level_prefix_cache(setups):
    """LRU-reclaimed prefix chains spill to host and later admissions
    promote them back — the prompt's prefill is skipped even though the
    device index lost the chain."""
    cfg, mesh, rules, params, aot = setups["smollm-360m"]
    ec = EngineConfig(
        max_slots=2, max_len=MAX_LEN, kv_layout="paged", page_size=BS,
        num_blocks=8, prefix_cache=True, host_tier=True)
    eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
    sys_prompt = np.arange(1, 17, dtype=np.int32)       # 2 full blocks

    def run(prompt, rid):
        eng.submit(prompt, max_new_tokens=4, rid=rid)
        guard = 0
        while eng.has_work():
            eng.step()
            eng.check_invariants()
            guard += 1
            assert guard < 200
        return list(eng.completions[rid].tokens)

    first = run(sys_prompt, 0)
    assert eng.alloc.num_cached > 0
    # churn the pool with disjoint prompts until the chain is reclaimed;
    # the on_evict hook spills each block to the tier as it dies
    rid = 1
    rng = np.random.default_rng(7)
    while eng.counters["prefix_spills"] == 0:
        run(rng.integers(100, cfg.vocab, 24).astype(np.int32), rid)
        rid += 1
        assert rid < 20, "pool churn never evicted the cached chain"
    assert eng.tier.spilled_blocks > 0
    # the same system prompt again: the device index misses, the host
    # tier promotes, and the covered positions skip prefill
    hits0 = eng.counters["prefix_hit_tokens"]
    again = run(sys_prompt, rid)
    assert again == first
    assert eng.counters["host_prefix_hits"] > 0, (
        "the spilled chain was never promoted from the host tier")
    assert eng.counters["prefix_hit_tokens"] > hits0


# ---------------------------------------------------------------------------
# HostTier unit behavior
# ---------------------------------------------------------------------------


def _lane(rid, nblocks=0, leaves=None, generated=1, prefilled=4):
    if leaves is not None:
        return LaneSpill(rid, "lane", prefilled, generated, leaves=leaves)
    blocks = [{"k": np.zeros((2, BS), np.float32)} for _ in range(nblocks)]
    return LaneSpill(rid, "paged", prefilled, generated, blocks=blocks)


def test_tier_bounded_budget_and_lru():
    tier = HostTier(capacity_blocks=3)
    pay = lambda: {"k": np.ones((2, BS), np.float32)}
    assert tier.put_block(b"a", pay()) and tier.put_block(b"b", pay())
    assert tier.put_block(b"c", pay())
    tier.check()
    assert tier.host_free == 0
    # a fourth block LRU-drops the oldest ("a"), never a lane spill
    assert tier.put_block(b"d", pay())
    assert not tier.has_block(b"a") and tier.has_block(b"d")
    assert tier.drops == 1
    # lane spills pin their units: a 3-block lane evicts every prefix
    # block; a 4-block lane cannot fit and is refused
    assert tier.put_lane(_lane(1, nblocks=3))
    assert tier.spilled_blocks == 3 and len(tier._prefix) == 0
    assert not tier.put_lane(_lane(2, nblocks=4))
    assert not tier.has_lane(2)
    tier.check()
    # whole-lane snapshots are outside the block budget
    assert tier.put_lane(_lane(3, leaves={"h": np.zeros(4, np.float32)}))
    tier.check()


def test_tier_match_chain_and_move_semantics():
    tier = HostTier()
    pay = lambda: {"k": np.ones(4, np.float32)}
    for key in (b"k0", b"k1", b"k2"):
        assert tier.put_block(key, pay())
    assert tier.match_chain([b"k0", b"k1", b"k2", b"k3"]) == 3
    assert tier.match_chain([b"k0", b"k1", b"k2"], start=1) == 2
    assert tier.match_chain([b"kX", b"k1"]) == 0
    # pop is a move: the key leaves the tier (device owns it now)
    assert tier.pop_block(b"k1") is not None
    assert not tier.has_block(b"k1")
    assert tier.match_chain([b"k0", b"k1"]) == 1
    # discard drops without counting a hit (republished on device)
    hits = tier.prefix_hits
    tier.discard_block(b"k2")
    assert not tier.has_block(b"k2") and tier.prefix_hits == hits
    tier.check()
    assert tier.used_bytes == 16    # only k0's payload remains


def test_tier_stale_lane_replaced():
    tier = HostTier()
    assert tier.put_lane(_lane(7, nblocks=1, generated=2))
    assert tier.put_lane(_lane(7, nblocks=2, generated=5))
    sp = tier.pop_lane(7)
    assert sp.generated == 5 and len(sp.blocks) == 2
    assert tier.pop_lane(7) is None
    tier.check()
    assert tier.used_bytes == 0


# ---------------------------------------------------------------------------
# Exact byte accounting (the integer-division truncation fix)
# ---------------------------------------------------------------------------


def test_exact_share_no_truncation():
    from repro.serve.engine import _exact_share

    # prime denominators: the old ``total // denom * units`` form loses
    # up to denom-1 bytes per unit; multiply-before-divide is exact at
    # the boundary and never over-counts
    for total, denom in ((1_000_003, 7), (12_345_679, 13), (997, 31)):
        assert _exact_share(total, denom, denom) == total
        assert _exact_share(total, 0, denom) == 0
        running = [_exact_share(total, u, denom) for u in range(denom + 1)]
        assert running == sorted(running)           # monotone in units
        assert all(v <= total for v in running)
        # the truncating form visibly under-counts on these totals
        assert any(_exact_share(total, u, denom) > u * (total // denom)
                   for u in range(denom + 1))


def test_kv_gauge_exact_with_prime_block_count(setups):
    """With a prime block count the per-block byte share is fractional;
    the gauge must report the exact multiply-before-divide value, not
    ``peak * (total // num_blocks)`` (which loses up to
    ``num_blocks - 1`` bytes per block counted)."""
    from repro.serve.engine import _exact_share

    cfg, mesh, rules, params, aot = setups["smollm-360m"]
    ec = EngineConfig(max_slots=2, max_len=MAX_LEN, kv_layout="paged",
                      page_size=BS, num_blocks=7)     # prime
    eng = ServeEngine(cfg, mesh, rules, params, ec, aot=aot)
    blocks = [eng.alloc.alloc() for _ in range(eng.alloc.num_free)]
    assert eng.alloc.peak_in_use == eng.alloc.capacity
    eng._note_kv_usage()
    want = _exact_share(eng.kv_reserved_bytes, eng.alloc.capacity,
                        eng._num_blocks)
    assert eng.obs.metrics.gauge("kv_peak_used_bytes").value == want
    # the exact form is tight: a full pool is within one block share of
    # the whole reservation, which the truncating form cannot guarantee
    # for totals the block count does not divide
    assert eng.kv_reserved_bytes - want \
        <= -(-eng.kv_reserved_bytes // eng._num_blocks)
    for b in blocks:
        eng.alloc.free(b)
