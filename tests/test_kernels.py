"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Per task spec: for each kernel, sweep shapes/dtypes and assert_allclose
against ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flat_adam.ops import flat_adam_op
from repro.kernels.rmsnorm.ops import rmsnorm_add_op, rmsnorm_op
from repro.kernels.rmsnorm.ref import rmsnorm_add_ref, rmsnorm_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_ref
from repro.optim.flat import flat_adam_update


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,Hk,D,bq,bk", [
    (128, 4, 2, 32, 32, 32),
    (256, 2, 2, 64, 128, 64),
    (64, 8, 1, 16, 64, 16),     # MQA
])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=48),
    dict(causal=True, softcap=30.0),
])
def test_flash_attention_sweep(dtype, S, H, Hk, D, bq, bk, kwargs):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(2, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(2, S, Hk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(2, S, Hk, D)), dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, **kwargs)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), **kwargs
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,D,br", [(64, 96, 16), (256, 128, 256), (8, 512, 8)])
def test_rmsnorm_sweep(dtype, rows, D, br):
    rng = np.random.default_rng(rows)
    x = jnp.asarray(rng.normal(size=(rows, D)), dtype)
    g = jnp.asarray(rng.normal(size=(D,)) * 0.1, dtype)
    np.testing.assert_allclose(
        np.asarray(rmsnorm_op(x, g, block_rows=br), np.float32),
        np.asarray(rmsnorm_ref(x, g), np.float32), **_tol(dtype))
    r = jnp.asarray(rng.normal(size=(rows, D)), dtype)
    n1, s1 = rmsnorm_add_op(x, r, g, block_rows=br)
    n2, s2 = rmsnorm_add_ref(x, r, g)
    np.testing.assert_allclose(np.asarray(n1, np.float32),
                               np.asarray(n2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s1, np.float32),
                               np.asarray(s2, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,H,P,G,N,chunk", [
    (64, 4, 16, 2, 8, 16),
    (128, 2, 8, 1, 16, 32),
    (32, 8, 32, 4, 4, 8),
])
def test_ssd_sweep(dtype, T, H, P, G, N, chunk):
    rng = np.random.default_rng(T + H)
    x = jnp.asarray(rng.normal(size=(2, H, T, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(2, H, T)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(2, G, T, N)), dtype)
    Cm = jnp.asarray(rng.normal(size=(2, G, T, N)), dtype)
    y = ssd_op(x, dt, A, Bm, Cm, chunk=chunk)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref, np.float32), **tol)


@pytest.mark.parametrize("n,block", [(1024, 256), (4096, 4096), (512, 64)])
@pytest.mark.parametrize("wd", [0.0, 0.01])
def test_flat_adam_sweep(n, block, wd):
    rng = np.random.default_rng(n)
    p = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    m = jnp.asarray(np.abs(rng.normal(size=(n,))) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=(n,))) * 0.1, jnp.float32)
    step = jnp.array([7], jnp.int32)
    p1, m1, v1 = flat_adam_op(p, g, m, v, step, lr=1e-3, weight_decay=wd,
                              block=block)
    p2, m2, v2 = flat_adam_update(p, g, m, v, jnp.int32(7), lr=1e-3)
    if wd:
        p2 = p2 - 1e-3 * wd * p
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)
