"""Expert-parallel MoE vs dense oracle (no-drop capacity => exact match)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.common import ShardRules
from repro.models.moe import expert_capacity, moe_ffn, moe_ffn_reference


def _setup(key, cfg, B, S):
    D, E, F = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    rw = jax.random.normal(ks[1], (D, E), jnp.float32) * 0.1
    wg = jax.random.normal(ks[2], (E, D, F), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (E, F, D), jnp.float32) * 0.1
    return x, rw, wg, wu, wd


def test_moe_matches_dense_oracle_no_drops(mesh, key):
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    # capacity >= all tokens: zero drops -> exact equality with the oracle
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts)),
    )
    rules = ShardRules.for_mesh(mesh)
    x, rw, wg, wu, wd = _setup(key, cfg, 2, 16)
    out, aux = jax.jit(
        lambda *a: moe_ffn(*a, cfg=cfg, mesh=mesh, rules=rules)
    )(x, rw, wg, wu, wd)
    ref = moe_ffn_reference(x, rw, wg, wu, wd, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux["drop_frac"]) == 0.0


def test_moe_capacity_drops_accounted(mesh, key):
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=0.02),
    )
    rules = ShardRules.for_mesh(mesh)
    x, rw, wg, wu, wd = _setup(key, cfg, 2, 64)
    out, aux = jax.jit(
        lambda *a: moe_ffn(*a, cfg=cfg, mesh=mesh, rules=rules)
    )(x, rw, wg, wu, wd)
    assert float(aux["drop_frac"]) > 0.0
    assert np.all(np.isfinite(np.asarray(out)))


def test_expert_capacity_floors():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    assert expert_capacity(8, cfg) >= 8        # decode floor
    c = expert_capacity(65536, cfg)
    assert c % 8 == 0
    assert c >= 65536 * cfg.moe.top_k / cfg.moe.num_experts


def test_moe_load_balance_loss_positive(mesh, key):
    cfg = dataclasses.replace(get_smoke_config("qwen3-moe-30b-a3b"),
                              compute_dtype="float32")
    rules = ShardRules.for_mesh(mesh)
    x, rw, wg, wu, wd = _setup(key, cfg, 2, 32)
    _, aux = jax.jit(
        lambda *a: moe_ffn(*a, cfg=cfg, mesh=mesh, rules=rules)
    )(x, rw, wg, wu, wd)
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # == 1 at perfect balance
