"""Observability layer: metrics registry, structured tracing, flight
recorder, and their wiring through the serve engine, router, AOT cache,
and train loop.

Coverage:
- histogram quantile accuracy vs exact percentiles (log-bucket sketches
  carry a bounded relative error) and merge == pooled-samples identity;
- counter/gauge semantics behind the ``MetricMap`` facade (monotone
  counters, absolute-set gauges, kind-mixing rejected);
- trace schema validation over real engine drives (preempt-and-requeue)
  and a router drive with a replica kill (failover) — every request's
  lifecycle starts at ``submit`` and ends at exactly one ``terminal``;
- an induced invariant failure dumps the flight recorder, and the
  failing request's full span timeline reconstructs from the dump alone;
- tracing is behavior-invisible: the same fuzz stream driven with the
  observer fully armed (fake clock shared engine<->tracer) is bitwise
  token-identical to the untraced drive with zero new executable builds;
- ``AotCache`` per-key build timing and the slowest-builds report.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.launch.mesh import _mk, single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.obs import (
    FlightRecorder, MetricMap, MetricsRegistry, Observer, Tracer,
    load_jsonl, merged_histogram, request_timeline, to_chrome_trace,
    to_jsonl, validate,
)
from repro.serve import EngineConfig, ServeEngine
from repro.serve.router import Router, RouterConfig

from test_engine_fuzz import make_stream

MAX_SLOTS, MAX_LEN = 3, 48
SLOTTED = EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def setup():
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    aot = AotCache("obs-test")
    ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot).prebuild()
    return cfg, mesh, rules, params, aot


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_monotone_gauge_absolute():
    reg = MetricsRegistry("t")
    c = reg.counter("hits")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.set(2)                       # counters never go backwards
    g = reg.gauge("depth")
    g.set(7)
    g.set(2)                           # gauges do
    assert g.value == 2
    g.set_max(5)
    g.set_max(3)                       # peak semantics
    assert g.value == 5
    reg.check()


def test_kind_mixing_rejected():
    reg = MetricsRegistry("t")
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")
    assert reg.kind("x") == "counter"
    assert reg.kind("nope") is None


def test_histogram_quantiles_match_exact_within_bucket_error():
    """The log-bucket sketch (growth 2**(1/4)) must land within ~10%
    relative error of exact percentiles on a heavy-tailed sample."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=1.0, sigma=1.2, size=5000)
    reg = MetricsRegistry("t")
    h = reg.histogram("lat")
    for x in xs:
        h.observe(float(x))
    for q in (0.50, 0.90, 0.99):
        exact = float(np.percentile(xs, 100 * q))
        approx = h.quantile(q)
        assert abs(approx - exact) / exact < 0.10, \
            f"p{int(q * 100)}: sketch {approx:.3f} vs exact {exact:.3f}"
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.mean == pytest.approx(xs.mean(), rel=1e-6)
    with pytest.raises(ValueError):
        h.observe(-1.0)
    with pytest.raises(ValueError):
        h.observe(float("nan"))


def test_histogram_merge_equals_pooled_samples():
    rng = np.random.default_rng(1)
    a, b = rng.exponential(5.0, 400), rng.exponential(50.0, 300)
    regs = [MetricsRegistry(f"r{i}") for i in range(3)]
    for x in a:
        regs[0].histogram("lat").observe(float(x))
    for x in b:
        regs[1].histogram("lat").observe(float(x))
    # regs[2] never observed "lat": merged_histogram must skip it
    merged = merged_histogram("lat", regs)
    pooled = MetricsRegistry("p").histogram("lat")
    for x in np.concatenate([a, b]):
        pooled.observe(float(x))
    assert merged.count == pooled.count == 700
    assert merged.buckets == pooled.buckets
    for q in (0.5, 0.9, 0.99):
        assert merged.quantile(q) == pooled.quantile(q)


def test_metricmap_facade_over_registry():
    reg = MetricsRegistry("t")
    m = MetricMap(reg, ("a", "b", "peak"), gauges=("peak",))
    m["a"] += 1
    m["a"] += 2
    m["b"] += 5
    m["peak"] = 10
    m["peak"] = 4                      # gauge: absolute set allowed
    assert m["a"] == 3 and m["peak"] == 4
    assert dict(m) == {"a": 3, "b": 5, "peak": 4}
    assert m.copy() == dict(m)
    assert m.get("nope", 0) == 0
    with pytest.raises(ValueError):
        m["b"] = 1                     # counter: decrease rejected
    with pytest.raises(TypeError):
        del m["a"]
    # the facade's values live in the registry (same snapshot source)
    snap = reg.snapshot()
    assert snap["a"] == {"kind": "counter", "value": 3}
    assert snap["peak"] == {"kind": "gauge", "value": 4}
    reg.check()


# ---------------------------------------------------------------------------
# Tracer + flight recorder units
# ---------------------------------------------------------------------------


def test_tracer_spans_balance_and_export(tmp_path):
    clock = FakeClock()
    tr = Tracer(clock)
    tr.mark("submit", 0, plen=4)
    clock.t = 1.0
    with tr.span("decode", track="engine", lanes=2):
        clock.t = 2.0
        tr.mark("first_token", 0)
    tr.mark("terminal", 0, status="ok")
    info = validate(tr.events)
    assert info == {"events": 5, "spans": 1, "requests": 1, "terminals": 1}
    assert [e["name"] for e in request_timeline(tr.events, 0)] \
        == ["submit", "first_token", "terminal"]

    p = to_jsonl(tr.events, str(tmp_path / "t.jsonl"))
    assert load_jsonl(p) == tr.events
    doc = to_chrome_trace(tr.events, str(tmp_path / "t.json"))
    rows = doc["traceEvents"]
    assert json.load(open(tmp_path / "t.json")) == doc
    # spans on the track tid, request instants on tid 1000+rid, ts in us
    b = next(r for r in rows if r["ph"] == "B")
    assert b["ts"] == pytest.approx(1e6)
    assert {r["tid"] for r in rows if r["ph"] == "i"} == {1000}
    assert any(r["ph"] == "M" and r["args"]["name"] == "request 0"
               for r in rows)


def test_validate_rejects_malformed_streams():
    tr = Tracer(FakeClock())
    sid = tr.begin("decode")
    with pytest.raises(AssertionError):
        validate(tr.events)            # span left open
    tr.end(sid)
    validate(tr.events)

    tr2 = Tracer(FakeClock())
    tr2.mark("admit", 3)               # lifecycle not starting at submit
    with pytest.raises(AssertionError):
        validate(tr2.events)

    tr3 = Tracer(FakeClock())
    tr3.mark("submit", 1)
    tr3.mark("terminal", 1, status="ok")
    tr3.mark("decode", 1)              # event after terminal
    with pytest.raises(AssertionError):
        validate(tr3.events)


def test_flight_recorder_ring_bounds_and_dump(tmp_path):
    clock = FakeClock()
    rec = FlightRecorder(capacity=8, clock=clock, dump_dir=str(tmp_path))
    for i in range(20):
        rec.record("tick", i=i)
    assert len(rec.events()) == 8
    assert rec.recorded == 20 and rec.dropped == 12
    assert [e["args"]["i"] for e in rec.events()] == list(range(12, 20))
    assert all(e["seq"] == 12 + j for j, e in enumerate(rec.events()))
    path = rec.dump("test_reason", context={"k": "v"})
    doc = json.load(open(path))
    assert doc["reason"] == "test_reason" and doc["context"] == {"k": "v"}
    assert doc["recorded"] == 20 and doc["dropped"] == 12
    assert len(doc["events"]) == 8
    assert rec.dumps == 1 and rec.last_dump == path


def test_flight_recorder_dump_names_never_collide(tmp_path):
    """Dump names come from scanning the directory, not a per-recorder
    counter: two recorders sharing a dump_dir (several engines, or a
    re-launched process after a crash) must never overwrite each other's
    dump 000 — the one artifact written because something went wrong."""
    a = FlightRecorder(capacity=4, clock=FakeClock(), dump_dir=str(tmp_path))
    b = FlightRecorder(capacity=4, clock=FakeClock(), dump_dir=str(tmp_path))
    a.record("from_a")
    b.record("from_b")
    paths = [a.dump("a0"), b.dump("b0"), a.dump("a1")]
    assert len(set(paths)) == 3, f"dump paths collided: {paths}"
    # every dump is still on disk with its own reason — nothing clobbered
    reasons = {json.load(open(p))["reason"] for p in paths}
    assert reasons == {"a0", "b0", "a1"}
    # a recorder in a fresh process (new instance, pre-existing dumps)
    # resumes after the highest existing index, gaps and all
    (tmp_path / "flightrec_041.json").write_text("{}")
    c = FlightRecorder(capacity=4, clock=FakeClock(), dump_dir=str(tmp_path))
    assert c.dump("c0").endswith("flightrec_042.json")


def test_observer_child_isolates_metrics_shares_timeline():
    obs = Observer.full(clock=FakeClock(), name="router")
    c0, c1 = obs.child("replica0"), obs.child("replica1")
    c0.metrics.counter("decode_steps").inc()
    c1.metrics.counter("decode_steps").inc(5)
    assert c0.metrics.counter("decode_steps").value == 1
    assert c1.metrics.counter("decode_steps").value == 5
    c0.mark("submit", 0, track=c0.name)
    c1.mark("submit", 1, track=c1.name)
    assert len(obs.tracer.events) == 2          # one shared timeline
    # tracer events flow into the recorder ring via the sink
    assert len(obs.recorder.events()) == 2


# ---------------------------------------------------------------------------
# Engine trace schema (preempt-and-requeue drive)
# ---------------------------------------------------------------------------


def test_engine_trace_schema_with_preempt(setup):
    cfg, mesh, rules, params, aot = setup
    clock = FakeClock()
    obs = Observer.full(clock=clock, name="engine")
    eng = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot,
                      obs=obs, clock=clock)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab, 6).astype(np.int32),
                       max_new_tokens=4)
            for _ in range(2 * MAX_SLOTS + 1)]
    tick = 0
    preempted_rid = None
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        if tick == 1 and eng.slots[0] is not None:
            preempted_rid = eng.slots[0].rid
            eng.preempt(0)
        clock.t += 1.0
        tick += 1
        assert tick < 200

    info = validate(obs.tracer.events)
    assert info["requests"] == len(rids)
    assert info["terminals"] == len(rids)       # drained: all terminal
    assert info["spans"] > 0                    # decode/prefill spans ran
    for rid in rids:
        names = [e["name"] for e in request_timeline(obs.tracer.events, rid)]
        assert names[0] == "submit" and names[-1] == "terminal"
        assert "admit" in names and "first_token" in names
    assert preempted_rid is not None
    names = [e["name"]
             for e in request_timeline(obs.tracer.events, preempted_rid)]
    assert "preempt" in names                   # and it still went terminal
    # ttft/tpot histograms populated for the ok status
    assert obs.metrics.histogram("ttft_ms_ok").count == len(rids)
    assert obs.metrics.histogram("tpot_ms_ok").count == len(rids)
    assert eng.counters["preemptions"] >= 1


def test_trace_zero_cost_when_disabled(setup):
    """No observer: the engine still counts (metrics are always live)
    but emits no events anywhere."""
    cfg, mesh, rules, params, aot = setup
    eng = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot)
    assert eng.obs.tracer is None and eng.obs.recorder is None
    eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=2)
    while eng.has_work():
        eng.step()
    assert eng.counters["decode_steps"] > 0
    assert eng.obs.metrics.histogram("ttft_ms_ok").count == 1
    assert eng.obs.dump("nothing") is None      # no recorder: no-op


# ---------------------------------------------------------------------------
# Flight-recorder dump on an induced invariant failure
# ---------------------------------------------------------------------------


def test_invariant_failure_dumps_flight_recorder(setup, tmp_path):
    cfg, mesh, rules, params, aot = setup
    clock = FakeClock()
    obs = Observer.full(clock=clock, dump_dir=str(tmp_path), name="engine")
    eng = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot,
                      obs=obs, clock=clock)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=3)
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        clock.t += 1.0
    # corrupt a status counter (monotone: upward is permitted by the
    # metric layer, caught by the conservation sweep)
    eng.counters["status_failed"] += 1
    with pytest.raises(AssertionError, match="status counters"):
        eng.check_invariants()
    assert obs.recorder.dumps == 1
    doc = json.load(open(obs.recorder.last_dump))
    assert doc["reason"] == "engine_invariant_failure"
    assert "status counters" in doc["context"]["error"]
    assert doc["context"]["counters"]["status_failed"] == 1
    # the failing request's full timeline reconstructs from the dump alone
    names = [e["name"] for e in request_timeline(doc["events"], rid)]
    assert names[0] == "submit" and names[-1] == "terminal"
    assert "admit" in names and "first_token" in names


# ---------------------------------------------------------------------------
# Tracing is behavior-invisible (fuzz stream, fake clock, builds-flat)
# ---------------------------------------------------------------------------


def test_traced_drive_is_bitwise_and_builds_flat(setup):
    cfg, mesh, rules, params, aot = setup

    def drive(obs):
        clock = FakeClock()
        if obs is not None:
            obs.tracer.clock = clock            # one clock, both views
        eng = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot,
                          obs=obs, clock=clock)
        stream = make_stream(np.random.default_rng(31337), cfg.vocab)
        i, tick = 0, 0
        while i < len(stream) or eng.has_work():
            while i < len(stream) and stream[i][0] <= tick:
                _, prompt, budget = stream[i]
                eng.submit(prompt, max_new_tokens=budget, rid=i)
                i += 1
            eng.step()
            eng.check_invariants()
            clock.t += 1.0
            tick += 1
            assert tick < 2000
        return [list(eng.completions[r].tokens) for r in range(len(stream))]

    builds0 = aot.stats["builds"]
    want = drive(None)
    obs = Observer.full(clock=FakeClock(), name="engine")
    got = drive(obs)
    assert got == want, "arming the observer changed greedy tokens"
    assert aot.stats["builds"] == builds0, \
        "tracing forced fresh executable builds"
    validate(obs.tracer.events)


# ---------------------------------------------------------------------------
# Router trace: failover + one terminal per request fleet-wide
# ---------------------------------------------------------------------------


def test_router_trace_failover_single_terminal(setup):
    cfg, mesh, rules, params, aot = setup
    clock = FakeClock()
    obs = Observer.full(clock=clock, name="router")
    router = Router(
        cfg, mesh, rules, params, SLOTTED,
        RouterConfig(replicas=2, shed_queue_depth=10_000),
        aot=aot, clock=clock, obs=obs)
    rng = np.random.default_rng(2)
    n = 6
    for i in range(n):
        router.submit(rng.integers(0, cfg.vocab, 6).astype(np.int32),
                      max_new_tokens=4, rid=i)
    tick = 0
    while router.has_work():
        router.step()
        router.check_invariants()
        if tick == 1:
            router.kill(1)             # strand replica 1's in-flight work
        clock.t += 1.0
        tick += 1
        assert tick < 500

    info = validate(obs.tracer.events)
    assert info["requests"] == n
    # exactly one terminal per rid fleet-wide, even across the failover
    assert info["terminals"] == n
    assert router.counters["failovers"] > 0
    failover_rids = {e["rid"] for e in obs.tracer.events
                     if e.get("cat") == "request"
                     and e["name"] == "failover"}
    assert failover_rids, "kill stranded nothing — failover gate vacuous"
    for rid in failover_rids:
        names = [e["name"] for e in request_timeline(obs.tracer.events, rid)]
        # route (router) precedes failover precedes the terminal
        assert names.index("route") < names.index("failover") \
            < names.index("terminal")
    # replica registries stay isolated; fleet latency merges cleanly
    regs = [router.obs.metrics] + [h.engine.obs.metrics
                                   for h in router.replicas]
    merged = merged_histogram("ttft_ms_ok", regs)
    assert merged.count == sum(
        1 for c in router.completions.values() if c.status == "ok")


# ---------------------------------------------------------------------------
# AotCache build profiling
# ---------------------------------------------------------------------------


def test_aot_build_timing_and_top_builds():
    obs = Observer.full(clock=FakeClock(), name="aot")
    aot = AotCache("t", obs=obs)
    aot.get("slow", lambda: sum(range(200_000)))
    aot.get("fast", lambda: 1)
    aot.get("slow", lambda: 1)                  # hit: no re-time
    assert aot.stats == {"builds": 2, "cache_hits": 1}
    assert set(aot.build_seconds) == {"slow", "fast"}
    assert all(s >= 0.0 for s in aot.build_seconds.values())
    assert aot.build_s_total == pytest.approx(
        sum(aot.build_seconds.values()))
    top = aot.top_builds(5)
    assert len(top) == 2
    assert [k for k, _ in top] == sorted(
        aot.build_seconds, key=aot.build_seconds.get, reverse=True)
    # each miss emitted one balanced aot_build span on the cache's track
    info = validate(obs.tracer.events)
    assert info["spans"] == 2
    assert all(e["name"] == "aot_build" and e["track"] == "t"
               for e in obs.tracer.events)


# ---------------------------------------------------------------------------
# Train loop profiling
# ---------------------------------------------------------------------------


def test_train_loop_traced_smoke():
    from repro.configs.base import ShapeConfig
    from repro.optim import OptConfig
    from repro.train import LoopConfig, TrainSettings, train

    mesh = _mk((1, 1), ("data", "model"))
    rules = ShardRules.for_mesh(mesh)
    cfg = get_smoke_config("smollm-360m")
    obs = Observer(tracer=Tracer(), name="train")
    res = train(cfg, ShapeConfig("t", "train", 16, 8), mesh, rules,
                OptConfig(kind="adam", lr=1e-2), TrainSettings(),
                LoopConfig(steps=2, ckpt_every=0, log_every=0), obs=obs)
    snap = res["metrics"]
    assert snap["step_ms"]["count"] == 2
    assert snap["step_ms"]["p50"] > 0
    info = validate(obs.tracer.events)
    names = [e["name"] for e in obs.tracer.events if e["ph"] == "B"]
    # four phase spans per step, every one balanced
    assert info["spans"] == 8
    for phase in ("stage_batch", "h2d", "dispatch", "device_wait"):
        assert names.count(phase) == 2
