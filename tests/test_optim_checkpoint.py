"""Optimizers, flat buffers, checkpoint manager, fault-tolerant resume."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.optim import (
    OptConfig, apply_update, flatten, global_norm, init_state, make_layout,
    unflatten,
)


@pytest.mark.parametrize("kind", ["sgd", "momentum", "rmsprop", "adam", "adamw"])
def test_optimizers_descend_quadratic(kind):
    opt = OptConfig(kind=kind, lr=0.05, weight_decay=0.01, grad_clip=10.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = init_state(opt, params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_update(opt, params, grads, state)
    assert float(loss(params)) < 0.2 * l0, kind


def test_grad_clipping():
    opt = OptConfig(kind="sgd", lr=1.0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = init_state(opt, params)
    grads = {"w": jnp.full(4, 100.0)}
    new, _, m = apply_update(opt, params, grads, state)
    np.testing.assert_allclose(float(global_norm({"w": new["w"]})), 1.0, rtol=1e-4)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


@settings(max_examples=20, deadline=None)
@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=6))
def test_flat_roundtrip_property(sizes):
    rng = np.random.default_rng(sum(sizes))
    tree = {f"p{i}": jnp.asarray(rng.normal(size=(s,)).astype(np.float32))
            for i, s in enumerate(sizes)}
    layout = make_layout(tree, align=16)
    buf = flatten(layout, tree)
    assert buf.shape[0] % 16 == 0
    back = unflatten(layout, buf)
    for k in tree:
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(tree[k]),
                                   rtol=1e-6)


def test_checkpoint_roundtrip_and_keep_k():
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_k=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": jax.tree.map(lambda x: x * s, tree)})
        assert mgr.all_steps() == [3, 4]          # keep_k pruned
        step, state = mgr.restore({"params": tree})
        assert step == 4
        np.testing.assert_allclose(np.asarray(state["params"]["a"]),
                                   np.arange(6.0).reshape(2, 3) * 4)


def test_checkpoint_atomicity_no_tmp_left():
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_k=3)
        mgr.save(5, {"params": tree}, blocking=False)
        mgr.wait()
        assert not any(f.startswith(".tmp") for f in os.listdir(d))
        assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"params": {"a": jnp.ones(3)}})
        with pytest.raises(ValueError, match="checkpoint"):
            mgr.restore({"params": {"a": jnp.ones(4)}})


def test_resume_matches_uninterrupted_run(mesh, rules):
    """Fault tolerance: crash-and-resume equals the uninterrupted run."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.train import LoopConfig, TrainSettings, train

    cfg = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", "train", 16, 8)
    opt = OptConfig(kind="adam", lr=1e-2)
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        # uninterrupted 6 steps
        ref = train(cfg, shape, mesh, rules, opt, TrainSettings(),
                    LoopConfig(steps=6, ckpt_every=0, ckpt_dir=d1, log_every=0))
        # interrupted: 3 steps + checkpoint, then resume to 6
        train(cfg, shape, mesh, rules, opt, TrainSettings(),
              LoopConfig(steps=3, ckpt_every=3, ckpt_dir=d2, log_every=0))
        res = train(cfg, shape, mesh, rules, opt, TrainSettings(),
                    LoopConfig(steps=6, ckpt_every=6, ckpt_dir=d2, log_every=0))
    np.testing.assert_allclose(res["final_loss"], ref["final_loss"],
                               rtol=1e-4, atol=1e-5)
